//! Shared paged KV-cache pool — the block allocator behind the serving
//! coordinator's memory bound.
//!
//! The pre-pool server reserved `max_batch × max_seq` worth of KV up front
//! for every slot regardless of use; a 32-position page granule plus
//! reservation-based admission replaces that with "pay for what you
//! decode". The pool owns a fixed budget of fixed-size pages (one page =
//! `page_size` positions × every layer × K and V strips, see
//! [`KvCache`][crate::nn::decode::KvCache] for the in-page layout) and
//! moves them through three states:
//!
//! 1. **reserved** — admission control promises a finishing sequence its
//!    whole footprint (`prompt + max_new`, clamped to `max_seq`) before the
//!    first token runs, so an admitted request can never strand mid-decode
//!    on an empty pool. A request whose footprint doesn't fit is *deferred*
//!    (left queued), never dropped.
//! 2. **in use** — pages physically attached to a slot's cache, handed out
//!    lazily as the sequence actually grows. Peak bytes are tracked here,
//!    which is what `ServeMetrics::peak_kv_bytes` reports.
//! 3. **free** — materialized buffers returned by finished sequences,
//!    recycled without touching the allocator again.
//!
//! Sequences leave the pool through one door — [`KvPool::release`] — however
//! they end (budget reached, stop token, cancellation), so a cancelled
//! request's whole reservation is back in the budget at the same tick
//! boundary the cancel takes effect.

use crate::nn::decode::{KvCache, KvPage};
use crate::nn::model::ModelConfig;

pub struct KvPool {
    page_size: usize,
    page_floats: usize,
    total_pages: usize,
    /// Pages promised to admitted sequences (includes attached ones).
    reserved: usize,
    /// Pages currently attached to a slot's cache.
    in_use: usize,
    peak_in_use: usize,
    /// Materialized-but-idle buffers, recycled across requests.
    free: Vec<KvPage>,
    /// Buffers ever materialized (lazy: short workloads never touch the
    /// full budget).
    materialized: usize,
}

impl KvPool {
    /// A pool with `total_pages` of budget, clamped up so a single
    /// `max_seq`-length sequence always fits (otherwise the head of the
    /// queue could never be admitted and the scheduler would stall).
    pub fn new(cfg: &ModelConfig, page_size: usize, total_pages: usize) -> KvPool {
        assert!(page_size > 0);
        let min_pages = cfg.max_seq.div_ceil(page_size);
        KvPool {
            page_size,
            page_floats: KvCache::page_floats_for(cfg, page_size),
            total_pages: total_pages.max(min_pages),
            reserved: 0,
            in_use: 0,
            peak_in_use: 0,
            free: Vec::new(),
            materialized: 0,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Bytes of one page, derived from the cache's element type (not a
    /// hard-coded 4-bytes-per-element).
    pub fn page_bytes(&self) -> usize {
        self.page_floats * std::mem::size_of::<f32>()
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Pages a sequence of `positions` total positions needs.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_size)
    }

    /// Pages not yet promised to an admitted sequence.
    pub fn unreserved_pages(&self) -> usize {
        self.total_pages - self.reserved
    }

    /// Admission control: promise `pages` to a sequence, or refuse and
    /// leave the budget untouched (the scheduler then defers the request —
    /// per-request deferral accounting lives there, since the pool sees
    /// every retry tick, not unique requests).
    pub fn try_reserve(&mut self, pages: usize) -> bool {
        if pages <= self.unreserved_pages() {
            self.reserved += pages;
            true
        } else {
            false
        }
    }

    /// Hand out one page from a prior reservation (recycles a free buffer
    /// when one exists, materializes otherwise).
    pub fn take_page(&mut self) -> KvPage {
        debug_assert!(self.in_use < self.reserved, "take_page without a covering reservation");
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        self.free.pop().unwrap_or_else(|| {
            self.materialized += 1;
            debug_assert!(self.materialized <= self.total_pages);
            vec![0.0f32; self.page_floats].into_boxed_slice()
        })
    }

    /// Reclaim a finished sequence's pages immediately and release its full
    /// reservation (`reserved` may exceed `pages.len()` when the sequence
    /// finished before touching its whole footprint).
    pub fn release(&mut self, pages: Vec<KvPage>, reserved: usize) {
        debug_assert!(pages.len() <= reserved);
        debug_assert!(pages.len() <= self.in_use && reserved <= self.reserved);
        self.in_use -= pages.len();
        self.reserved -= reserved;
        self.free.extend(pages);
    }

    /// Pages currently attached to a sequence's cache.
    pub fn in_use_pages(&self) -> usize {
        self.in_use
    }

    /// Pages currently promised to admitted sequences (attached or not).
    pub fn reserved_pages(&self) -> usize {
        self.reserved
    }

    /// Materialized-but-idle page buffers available for recycling.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Restart peak tracking from the current occupancy (reservations and
    /// attached pages are untouched). [`crate::serve::Engine::reset`] calls
    /// this so each reset lifetime reports its own peak.
    pub fn reset_stats(&mut self) {
        self.peak_in_use = self.in_use;
    }

    /// Peak bytes of KV pages simultaneously attached to sequences — the
    /// pool's actual footprint, measurably below the old
    /// `max_batch × max_seq` reservation on short-prompt workloads.
    pub fn peak_bytes(&self) -> usize {
        self.peak_in_use * self.page_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::family_config;

    fn cfg() -> ModelConfig {
        family_config("l2", "xs")
    }

    #[test]
    fn reserve_take_release_roundtrip() {
        let cfg = cfg();
        let mut pool = KvPool::new(&cfg, 4, 100);
        assert_eq!(pool.pages_for(1), 1);
        assert_eq!(pool.pages_for(4), 1);
        assert_eq!(pool.pages_for(5), 2);
        assert!(pool.try_reserve(3));
        assert_eq!(pool.unreserved_pages(), 97);
        let a = pool.take_page();
        let b = pool.take_page();
        assert_eq!(a.len(), KvCache::page_floats_for(&cfg, 4));
        assert_eq!(pool.in_use_pages(), 2);
        // Finished early: only 2 of the 3 reserved pages were touched.
        pool.release(vec![a, b], 3);
        assert_eq!(pool.in_use_pages(), 0);
        assert_eq!(pool.unreserved_pages(), 100);
        assert_eq!(pool.peak_bytes(), 2 * pool.page_bytes());
        // Buffers are recycled, not re-materialized.
        assert!(pool.try_reserve(1));
        let _c = pool.take_page();
        assert_eq!(pool.materialized, 2);
    }

    #[test]
    fn exhausted_budget_refuses_until_released() {
        let mut pool = KvPool::new(&cfg(), 4, 8);
        assert!(pool.try_reserve(8));
        assert!(!pool.try_reserve(1));
        assert_eq!(pool.unreserved_pages(), 0);
        pool.release(Vec::new(), 8);
        assert!(pool.try_reserve(1));
    }

    #[test]
    fn stats_reset_and_free_list_accounting() {
        let cfg = cfg();
        let mut pool = KvPool::new(&cfg, 4, 16);
        assert!(pool.try_reserve(4));
        let a = pool.take_page();
        let b = pool.take_page();
        assert_eq!(pool.reserved_pages(), 4);
        assert_eq!(pool.free_pages(), 0);
        pool.release(vec![a, b], 4);
        assert_eq!(pool.reserved_pages(), 0);
        assert_eq!(pool.free_pages(), 2);
        assert_eq!(pool.peak_bytes(), 2 * pool.page_bytes());
        // reset_stats restarts peak tracking from current occupancy (0).
        pool.reset_stats();
        assert_eq!(pool.peak_bytes(), 0);
        assert!(pool.try_reserve(1));
        let c = pool.take_page();
        assert_eq!(pool.peak_bytes(), pool.page_bytes());
        pool.release(vec![c], 1);
    }

    #[test]
    fn budget_clamps_to_one_full_sequence() {
        let cfg = cfg();
        let pool = KvPool::new(&cfg, 4, 0);
        assert_eq!(pool.total_pages(), cfg.max_seq.div_ceil(4));
    }
}
