//! Byte-level tokenizer.
//!
//! The synthetic corpora are ASCII, so a byte vocabulary (256) plus a BOS
//! token (id 256) covers everything with zero out-of-vocabulary risk —
//! the same trade the paper's models make at the other extreme (BPE over a
//! 32k–256k vocab). Vocab size 257 keeps the embedding/head matrices small
//! enough for the in-repo teachers.

/// Total vocabulary size (256 bytes + BOS).
pub const VOCAB_SIZE: usize = 257;

/// Beginning-of-sequence token id.
pub const BOS: u16 = 256;

/// Encode text to token ids.
pub fn tokenize(text: &str) -> Vec<u16> {
    text.bytes().map(|b| b as u16).collect()
}

/// Decode token ids back to text (skips BOS; lossy on invalid UTF-8).
pub fn detokenize(tokens: &[u16]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t < 256)
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "the robin lives in the forest. 123!";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn bos_is_out_of_byte_range() {
        assert!(BOS as usize >= 256);
        assert!((BOS as usize) < VOCAB_SIZE);
        assert_eq!(detokenize(&[BOS, b'h' as u16, b'i' as u16]), "hi");
    }

    #[test]
    fn tokens_are_bytes() {
        assert_eq!(tokenize("ab"), vec![97, 98]);
    }
}
