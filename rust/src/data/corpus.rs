//! Seeded synthetic corpora.
//!
//! Substitutes for the paper's WikiText-2 and C4: two *distinct* text
//! distributions generated from a shared knowledge base, so that
//! (a) next-token perplexity is meaningful and sensitive to quantization,
//! (b) the zero-shot tasks in [`super::tasks`] are answerable from corpus
//! facts, and (c) the calibration-mixture ablation (paper App. D.2) has a
//! genuine train/eval distribution shift to exhibit.

use crate::util::rng::Rng;

/// Which synthetic distribution to sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// Clean prose built from the knowledge base + filler grammar
    /// (WikiText-2 stand-in).
    SynthText,
    /// Noisy web-like mixture: headers, URLs, numbers, casing noise
    /// (C4 stand-in).
    WebMix,
}

impl CorpusKind {
    pub fn parse(s: &str) -> CorpusKind {
        match s {
            "synthtext" | "wikitext" | "wt2" => CorpusKind::SynthText,
            "webmix" | "c4" => CorpusKind::WebMix,
            _ => panic!("unknown corpus kind '{s}' (expected synthtext|webmix)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::SynthText => "synthtext",
            CorpusKind::WebMix => "webmix",
        }
    }
}

/// (entity, category, place, color)
pub const ENTITIES: &[(&str, &str, &str, &str)] = &[
    ("robin", "bird", "forest", "red"),
    ("sparrow", "bird", "meadow", "brown"),
    ("eagle", "bird", "mountain", "golden"),
    ("owl", "bird", "barn", "grey"),
    ("crow", "bird", "field", "black"),
    ("heron", "bird", "marsh", "white"),
    ("salmon", "fish", "river", "silver"),
    ("trout", "fish", "lake", "spotted"),
    ("shark", "fish", "ocean", "grey"),
    ("carp", "fish", "pond", "golden"),
    ("wolf", "mammal", "forest", "grey"),
    ("fox", "mammal", "den", "red"),
    ("bear", "mammal", "cave", "brown"),
    ("deer", "mammal", "meadow", "tan"),
    ("rabbit", "mammal", "burrow", "white"),
    ("mouse", "mammal", "barn", "grey"),
    ("otter", "mammal", "river", "brown"),
    ("oak", "tree", "valley", "green"),
    ("pine", "tree", "mountain", "green"),
    ("birch", "tree", "forest", "white"),
    ("willow", "tree", "riverbank", "silver"),
    ("maple", "tree", "park", "red"),
    ("rose", "flower", "garden", "red"),
    ("tulip", "flower", "field", "yellow"),
    ("daisy", "flower", "meadow", "white"),
    ("lily", "flower", "pond", "pink"),
    ("violet", "flower", "woodland", "purple"),
];

/// (tool, use)
pub const TOOLS: &[(&str, &str)] = &[
    ("hammer", "drive nails"),
    ("saw", "cut wood"),
    ("needle", "sew cloth"),
    ("spoon", "stir soup"),
    ("kettle", "boil water"),
    ("broom", "sweep floors"),
    ("ladder", "reach high shelves"),
    ("shovel", "dig holes"),
    ("knife", "slice bread"),
    ("lantern", "light the path"),
];

/// (cause, effect) continuations for the HellaSwag-like task.
pub const CAUSE_EFFECT: &[(&str, &str)] = &[
    ("when the rain falls", "the river rises"),
    ("when the sun sets", "the sky darkens"),
    ("when the wind blows", "the leaves fall"),
    ("when winter comes", "the lake freezes"),
    ("when the fire burns", "the smoke rises"),
    ("when the snow melts", "the streams flood"),
    ("when the night ends", "the birds sing"),
    ("when the storm passes", "the air clears"),
    ("when the seed sprouts", "the roots spread"),
    ("when the moon rises", "the tide turns"),
];

const FILLER_SUBJECTS: &[&str] =
    &["the farmer", "the child", "the traveler", "an old woman", "the miller", "a young boy"];
const FILLER_VERBS: &[&str] =
    &["walked to", "looked at", "remembered", "found", "returned to", "watched"];
const FILLER_OBJECTS: &[&str] = &[
    "the village",
    "the market",
    "the old bridge",
    "the quiet road",
    "the stone wall",
    "the harvest",
];

/// Distinct categories in the knowledge base.
pub fn categories() -> Vec<&'static str> {
    let mut cats: Vec<&str> = ENTITIES.iter().map(|e| e.1).collect();
    cats.sort();
    cats.dedup();
    cats
}

fn fact_sentence(rng: &mut Rng) -> String {
    let (name, cat, place, color) = *rng_choose(rng, ENTITIES);
    match rng.below(6) {
        0 => format!("the {name} is a kind of {cat}."),
        1 => format!("the {name} lives in the {place}."),
        2 => format!("the {name} is {color}."),
        3 => {
            // Boolean QA form, both polarities, so yes/no scoring is learnable.
            if rng.below(2) == 0 {
                format!("is the {name} a {cat}? yes.")
            } else {
                let other = other_category(rng, cat);
                format!("is the {name} a {other}? no.")
            }
        }
        4 => {
            // Plural agreement (WinoGrande-like minimal pair material).
            format!("the {name}s are {color}.")
        }
        _ => {
            let (tool, use_) = *rng_choose(rng, TOOLS);
            format!("you can use a {tool} to {use_}.")
        }
    }
}

fn other_category(rng: &mut Rng, not: &str) -> &'static str {
    let cats = categories();
    loop {
        let c = cats[rng.below(cats.len())];
        if c != not {
            return c;
        }
    }
}

fn cause_effect_sentence(rng: &mut Rng) -> String {
    let (c, e) = *rng_choose(rng, CAUSE_EFFECT);
    format!("{c}, {e}.")
}

fn filler_sentence(rng: &mut Rng) -> String {
    format!(
        "{} {} {}.",
        rng_choose(rng, FILLER_SUBJECTS),
        rng_choose(rng, FILLER_VERBS),
        rng_choose(rng, FILLER_OBJECTS)
    )
}

fn rng_choose<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len())]
}

fn synthtext_sentence(rng: &mut Rng) -> String {
    // Fact-heavy mixture keeps the corpus learnable at small scale.
    match rng.categorical(&[5.0, 2.0, 3.0]) {
        0 => fact_sentence(rng),
        1 => cause_effect_sentence(rng),
        _ => filler_sentence(rng),
    }
}

fn webmix_chunk(rng: &mut Rng) -> String {
    match rng.categorical(&[4.0, 1.0, 1.0, 1.0, 1.0]) {
        0 => {
            // Facts still appear, but with casing noise.
            let s = synthtext_sentence(rng);
            if rng.below(3) == 0 {
                let mut c = s.chars();
                match c.next() {
                    Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
                    None => s,
                }
            } else {
                s
            }
        }
        1 => format!("== {} ==", rng_choose(rng, FILLER_OBJECTS).to_uppercase()),
        2 => format!(
            "http://site{}.example/page{}?id={}",
            rng.below(90),
            rng.below(900),
            rng.below(10_000)
        ),
        3 => format!("{}, {}, {}", rng.below(1000), rng.below(1000), rng.below(1000)),
        _ => format!(
            "{} kg of {} cost {} coins",
            rng.below(50) + 1,
            rng_choose(rng, ENTITIES).0,
            rng.below(500) + 1
        ),
    }
}

/// Generate at least `min_bytes` of corpus text.
pub fn gen_corpus(kind: CorpusKind, min_bytes: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed ^ 0x5EED_C0DE);
    let mut out = String::with_capacity(min_bytes + 128);
    let mut sentence_in_par = 0usize;
    while out.len() < min_bytes {
        let chunk = match kind {
            CorpusKind::SynthText => synthtext_sentence(&mut rng),
            CorpusKind::WebMix => webmix_chunk(&mut rng),
        };
        out.push_str(&chunk);
        sentence_in_par += 1;
        if sentence_in_par >= 5 + rng.below(5) {
            out.push('\n');
            sentence_in_par = 0;
        } else {
            out.push(' ');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = gen_corpus(CorpusKind::SynthText, 10_000, 42);
        let b = gen_corpus(CorpusKind::SynthText, 10_000, 42);
        assert_eq!(a, b);
        let c = gen_corpus(CorpusKind::SynthText, 10_000, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn meets_size_and_is_ascii() {
        let s = gen_corpus(CorpusKind::WebMix, 50_000, 0);
        assert!(s.len() >= 50_000);
        assert!(s.is_ascii());
    }

    #[test]
    fn distributions_differ() {
        let a = gen_corpus(CorpusKind::SynthText, 50_000, 0);
        let b = gen_corpus(CorpusKind::WebMix, 50_000, 0);
        assert!(!a.contains("http://"));
        assert!(b.contains("http://"));
    }

    #[test]
    fn facts_appear_in_both() {
        for kind in [CorpusKind::SynthText, CorpusKind::WebMix] {
            let s = gen_corpus(kind, 200_000, 7);
            assert!(s.contains("is a kind of"), "{kind:?} missing facts");
            assert!(s.contains("you can use a"), "{kind:?} missing tool facts");
        }
    }

    #[test]
    fn knowledge_base_consistency() {
        // Every entity category is in categories(); names are lowercase ascii.
        let cats = categories();
        for (name, cat, _, _) in ENTITIES {
            assert!(cats.contains(cat));
            assert!(name.chars().all(|c| c.is_ascii_lowercase()));
        }
        assert!(cats.len() >= 4);
    }
}
