//! Data substrate: synthetic corpora (substituting WikiText-2 / C4), the
//! byte-level tokenizer, sequence batching, and the synthetic zero-shot
//! task suite (substituting ARC/BoolQ/HellaSwag/WinoGrande/PIQA).

pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{gen_corpus, CorpusKind};
pub use tasks::{gen_task, score_tasks, McItem, TaskKind, ALL_TASKS};
pub use tokenizer::{detokenize, tokenize, BOS, VOCAB_SIZE};

use crate::util::rng::Rng;

/// Sample `count` training/calibration sequences of `seq_len` tokens from a
/// token stream, each prefixed with BOS (sampling calibration windows the
/// way the paper samples 128 WikiText-2 sequences).
pub fn sample_sequences(
    tokens: &[u16],
    seq_len: usize,
    count: usize,
    rng: &mut Rng,
) -> Vec<Vec<u16>> {
    assert!(tokens.len() > seq_len + 1, "corpus too small for seq_len {seq_len}");
    (0..count)
        .map(|_| {
            let start = rng.below(tokens.len() - seq_len - 1);
            let mut seq = Vec::with_capacity(seq_len);
            seq.push(BOS);
            seq.extend_from_slice(&tokens[start..start + seq_len - 1]);
            seq
        })
        .collect()
}

/// Contiguous non-overlapping evaluation windows (the conventional
/// WikiText-2 perplexity protocol).
pub fn eval_windows(tokens: &[u16], seq_len: usize, max_windows: usize) -> Vec<Vec<u16>> {
    let mut out = Vec::new();
    let mut pos = 0;
    while pos + seq_len < tokens.len() && out.len() < max_windows {
        let mut seq = Vec::with_capacity(seq_len);
        seq.push(BOS);
        seq.extend_from_slice(&tokens[pos..pos + seq_len - 1]);
        out.push(seq);
        pos += seq_len - 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_shapes_and_bos() {
        let toks: Vec<u16> = (0..10_000).map(|i| (i % 250) as u16).collect();
        let mut rng = Rng::new(0);
        let seqs = sample_sequences(&toks, 64, 10, &mut rng);
        assert_eq!(seqs.len(), 10);
        for s in &seqs {
            assert_eq!(s.len(), 64);
            assert_eq!(s[0], BOS);
        }
    }

    #[test]
    fn eval_windows_cover_stream_without_overlap() {
        let toks: Vec<u16> = (0..1000).map(|i| (i % 250) as u16).collect();
        let w = eval_windows(&toks, 101, usize::MAX);
        assert!(w.len() >= 8);
        assert_eq!(&w[0][1..], &toks[0..100]);
        assert_eq!(w[1][1], toks[100]);
    }
}
