//! Synthetic zero-shot task suite.
//!
//! Six multiple-choice tasks mirroring the paper's evaluation set
//! (ARC-Easy, ARC-Challenge, BoolQ, HellaSwag, WinoGrande, PIQA), built from
//! the same knowledge base as the corpora so that a teacher trained on the
//! corpus performs well above chance. Scoring follows the standard
//! likelihood protocol: each choice is appended to the prompt and the
//! choice with the highest length-normalized log-probability wins.

use super::corpus::{categories, CAUSE_EFFECT, ENTITIES, TOOLS};
use crate::util::rng::Rng;

/// A multiple-choice item.
#[derive(Clone, Debug)]
pub struct McItem {
    pub prompt: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

/// The six tasks (paper analogue in parentheses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Category completion, distractors from other categories (ARC-Easy).
    CategoryEasy,
    /// Property question, distractors from the *same* category (ARC-Challenge).
    PropertyHard,
    /// Yes/no fact verification (BoolQ).
    BoolFact,
    /// Most plausible continuation (HellaSwag).
    Continuation,
    /// Singular/plural agreement minimal pairs (WinoGrande).
    Agreement,
    /// Tool affordances (PIQA).
    Affordance,
}

pub const ALL_TASKS: &[TaskKind] = &[
    TaskKind::CategoryEasy,
    TaskKind::PropertyHard,
    TaskKind::BoolFact,
    TaskKind::Continuation,
    TaskKind::Agreement,
    TaskKind::Affordance,
];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::CategoryEasy => "ARC-e*",
            TaskKind::PropertyHard => "ARC-c*",
            TaskKind::BoolFact => "BoolQ*",
            TaskKind::Continuation => "Hella*",
            TaskKind::Agreement => "Wino*",
            TaskKind::Affordance => "PIQA*",
        }
    }
}

fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len())]
}

/// Generate `n` items of a task. Deterministic in (kind, seed).
pub fn gen_task(kind: TaskKind, n: usize, seed: u64) -> Vec<McItem> {
    let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x9E37_79B9));
    (0..n).map(|_| gen_item(kind, &mut rng)).collect()
}

fn gen_item(kind: TaskKind, rng: &mut Rng) -> McItem {
    match kind {
        TaskKind::CategoryEasy => {
            let (name, cat, _, _) = *pick(rng, ENTITIES);
            let mut choices: Vec<String> = vec![cat.to_string()];
            let cats = categories();
            while choices.len() < 4 {
                let c = cats[rng.below(cats.len())];
                if !choices.iter().any(|x| x == c) {
                    choices.push(c.to_string());
                }
            }
            shuffle_with_answer(rng, format!("the {name} is a kind of"), choices, 0)
        }
        TaskKind::PropertyHard => {
            // Distractor colors drawn from same-category entities: harder.
            let (name, cat, _, color) = *pick(rng, ENTITIES);
            let mut choices: Vec<String> = vec![color.to_string()];
            let same_cat: Vec<&str> = ENTITIES
                .iter()
                .filter(|e| e.1 == cat && e.3 != color)
                .map(|e| e.3)
                .collect();
            let mut pool: Vec<&str> = if same_cat.len() >= 3 {
                same_cat
            } else {
                ENTITIES.iter().filter(|e| e.3 != color).map(|e| e.3).collect()
            };
            pool.sort();
            pool.dedup();
            rng.shuffle(&mut pool);
            for c in pool {
                if choices.len() >= 4 {
                    break;
                }
                if !choices.iter().any(|x| x == c) {
                    choices.push(c.to_string());
                }
            }
            shuffle_with_answer(rng, format!("the {name} is"), choices, 0)
        }
        TaskKind::BoolFact => {
            let (name, cat, _, _) = *pick(rng, ENTITIES);
            let truthy = rng.below(2) == 0;
            let asked_cat = if truthy {
                cat.to_string()
            } else {
                let cats = categories();
                loop {
                    let c = cats[rng.below(cats.len())];
                    if c != cat {
                        break c.to_string();
                    }
                }
            };
            McItem {
                prompt: format!("is the {name} a {asked_cat}?"),
                choices: vec![" yes.".into(), " no.".into()],
                answer: if truthy { 0 } else { 1 },
            }
        }
        TaskKind::Continuation => {
            let idx = rng.below(CAUSE_EFFECT.len());
            let (cause, effect) = CAUSE_EFFECT[idx];
            let mut choices = vec![effect.to_string()];
            while choices.len() < 4 {
                let (_, e2) = *pick(rng, CAUSE_EFFECT);
                if !choices.iter().any(|x| x == e2) {
                    choices.push(e2.to_string());
                }
            }
            let choices = choices.into_iter().map(|e| format!(" {e}.")).collect();
            shuffle_with_answer_pre(rng, format!("{cause},"), choices, 0)
        }
        TaskKind::Agreement => {
            let (name, _, _, color) = *pick(rng, ENTITIES);
            let plural = rng.below(2) == 0;
            let (subject, correct, wrong) = if plural {
                (format!("the {name}s"), " are", " is")
            } else {
                (format!("the {name}"), " is", " are")
            };
            McItem {
                prompt: subject,
                choices: vec![format!("{correct} {color}."), format!("{wrong} {color}.")],
                answer: 0,
            }
        }
        TaskKind::Affordance => {
            let idx = rng.below(TOOLS.len());
            let (tool, use_) = TOOLS[idx];
            let mut choices = vec![use_.to_string()];
            while choices.len() < 4 {
                let (_, u2) = *pick(rng, TOOLS);
                if !choices.iter().any(|x| x == u2) {
                    choices.push(u2.to_string());
                }
            }
            let choices = choices.into_iter().map(|u| format!(" {u}.")).collect();
            shuffle_with_answer_pre(rng, format!("you can use a {tool} to"), choices, 0)
        }
    }
}

/// Shuffle choices of a "prompt + ' ' + choice" item, tracking the answer.
fn shuffle_with_answer(
    rng: &mut Rng,
    prompt: String,
    choices: Vec<String>,
    answer: usize,
) -> McItem {
    let choices = choices.into_iter().map(|c| format!(" {c}.")).collect();
    shuffle_with_answer_pre(rng, prompt, choices, answer)
}

/// As above but choices are already fully formatted (with leading space).
fn shuffle_with_answer_pre(
    rng: &mut Rng,
    prompt: String,
    mut choices: Vec<String>,
    answer: usize,
) -> McItem {
    let correct = choices[answer].clone();
    rng.shuffle(&mut choices);
    let answer = choices.iter().position(|c| *c == correct).unwrap();
    McItem { prompt, choices, answer }
}

/// Score a task: `logprob(prompt, choice)` must return the total
/// log-probability of the choice tokens given the prompt. Returns accuracy
/// in percent. Length-normalized (mean per-token logprob), the lm-eval
/// convention for multi-token choices.
pub fn score_tasks(
    items: &[McItem],
    mut logprob: impl FnMut(&str, &str) -> f64,
) -> f64 {
    let mut correct = 0usize;
    for item in items {
        let mut best = f64::NEG_INFINITY;
        let mut best_idx = 0;
        for (i, choice) in item.choices.iter().enumerate() {
            let lp = logprob(&item.prompt, choice) / choice.len().max(1) as f64;
            if lp > best {
                best = lp;
                best_idx = i;
            }
        }
        if best_idx == item.answer {
            correct += 1;
        }
    }
    100.0 * correct as f64 / items.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_wellformed() {
        for &kind in ALL_TASKS {
            let a = gen_task(kind, 50, 1);
            let b = gen_task(kind, 50, 1);
            assert_eq!(a.len(), 50);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.choices, y.choices);
                assert_eq!(x.answer, y.answer);
                assert!(x.answer < x.choices.len());
                assert!(x.choices.len() >= 2);
                // Choices distinct.
                let mut c = x.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), x.choices.len(), "dup choices in {:?}", x);
            }
        }
    }

    #[test]
    fn oracle_scorer_gets_100() {
        // A scorer that knows the answer via string matching of the true fact.
        let items = gen_task(TaskKind::CategoryEasy, 30, 2);
        let acc = score_tasks(&items, |prompt, choice| {
            // "the robin is a kind of" + " bird." — consult the KB.
            let name = prompt.split_whitespace().nth(1).unwrap();
            let truth = ENTITIES.iter().find(|e| e.0 == name).unwrap().1;
            if choice.contains(truth) {
                0.0
            } else {
                -1.0
            }
        });
        assert_eq!(acc, 100.0);
    }

    #[test]
    fn random_scorer_near_chance() {
        let items = gen_task(TaskKind::CategoryEasy, 400, 3);
        let mut rng = Rng::new(9);
        let acc = score_tasks(&items, |_, _| rng.uniform());
        assert!(acc > 10.0 && acc < 40.0, "acc={acc}");
    }

    #[test]
    fn boolq_has_balanced_answers() {
        let items = gen_task(TaskKind::BoolFact, 400, 4);
        let yes = items.iter().filter(|i| i.answer == 0).count();
        assert!(yes > 140 && yes < 260, "yes={yes}");
    }

    #[test]
    fn length_normalization_used() {
        // A long wrong choice must not win just by token count when
        // per-token logprob favors the short right one.
        let items = vec![McItem {
            prompt: "p".into(),
            choices: vec![" aaaa.".into(), " b.".into()],
            answer: 1,
        }];
        // total logprob proportional to -0.1*len for choice 0, -0.05*len for 1
        let acc = score_tasks(&items, |_, c| {
            if c.contains('a') {
                -0.1 * c.len() as f64
            } else {
                -0.05 * c.len() as f64
            }
        });
        assert_eq!(acc, 100.0);
    }
}
