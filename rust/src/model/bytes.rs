//! Artifact byte backing and the `WeightBytes` Cow view.
//!
//! A [`ByteStore`] owns the raw bytes of one model artifact — either a
//! read-once heap buffer or, on 64-bit unix, a read-only `mmap` of the
//! file. [`WeightBytes<T>`] is the Cow-style slice the weight containers
//! (`quant::pack::PackedBits` words, `quant::scheme::QuantLinear` scales)
//! actually hold: it is *either* an owned `Vec<T>` (the training /
//! quantization path, byte-for-byte the old representation) *or* a typed
//! borrow into an `Arc<ByteStore>` (the zero-copy serving path). Both
//! deref to `&[T]`, so every kernel reads through one code path.
//!
//! Zero-copy soundness: a borrowed view is only constructed when the byte
//! range is in bounds, 4-byte aligned, and the target is little-endian
//! (the on-disk byte order). On big-endian targets the constructor
//! decodes into an owned buffer instead, so readers stay correct
//! everywhere and zero-copy is a transparent fast path. The `Arc` keeps
//! the mapping alive for as long as any view exists — an engine holding
//! borrowed weights can never outlive its mapping, whatever the registry
//! does (see `model::store`).

use std::io::Read;
use std::sync::Arc;

/// Which backing [`ByteStore::open`] should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backing {
    /// `mmap` the file read-only (64-bit unix; silently falls back to
    /// [`Backing::Heap`] elsewhere). Pages are faulted in on first touch,
    /// so cold load time is O(header) and resident memory tracks what the
    /// forward pass actually reads.
    Mmap,
    /// Read the whole file into one heap buffer up front.
    Heap,
}

enum Storage {
    Heap(Box<[u8]>),
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped {
        ptr: *const u8,
        len: usize,
    },
}

/// Owner of one artifact's bytes (heap buffer or read-only file mapping).
pub struct ByteStore {
    storage: Storage,
}

// SAFETY: the mapped variant is a private read-only mapping (PROT_READ,
// MAP_PRIVATE) of a regular file; no writer exists, so shared references
// from any thread are sound. The heap variant is a plain owned buffer.
unsafe impl Send for ByteStore {}
unsafe impl Sync for ByteStore {}

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};
    // Bound directly against the libc `std` already links — the crate
    // itself stays dependency-free.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
}

impl ByteStore {
    /// Open `path` with the requested backing. `Mmap` falls back to `Heap`
    /// on platforms without the mapping path or for empty files (a
    /// zero-length `mmap` is an error by POSIX).
    pub fn open(path: &str, backing: Backing) -> std::io::Result<Arc<ByteStore>> {
        match backing {
            Backing::Heap => Self::read_heap(path),
            Backing::Mmap => Self::map_file(path),
        }
    }

    fn read_heap(path: &str) -> std::io::Result<Arc<ByteStore>> {
        let mut f = std::fs::File::open(path)?;
        let hint = f.metadata().map(|m| m.len() as usize).unwrap_or(0);
        let mut buf = Vec::with_capacity(hint);
        f.read_to_end(&mut buf)?;
        Ok(Arc::new(ByteStore { storage: Storage::Heap(buf.into_boxed_slice()) }))
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn map_file(path: &str) -> std::io::Result<Arc<ByteStore>> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            return Self::read_heap(path);
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1.
        if ptr as isize == -1 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                format!("mmap failed for {path}"),
            ));
        }
        // The fd may be closed once the mapping exists (POSIX keeps the
        // mapping valid); `f` drops here.
        Ok(Arc::new(ByteStore { storage: Storage::Mapped { ptr: ptr as *const u8, len } }))
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn map_file(path: &str) -> std::io::Result<Arc<ByteStore>> {
        Self::read_heap(path)
    }

    /// The full artifact contents.
    pub fn bytes(&self) -> &[u8] {
        match &self.storage {
            Storage::Heap(b) => b,
            #[cfg(all(unix, target_pointer_width = "64"))]
            Storage::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
        }
    }

    /// Whether this store is a file mapping (vs a heap copy).
    pub fn is_mapped(&self) -> bool {
        match &self.storage {
            Storage::Heap(_) => false,
            #[cfg(all(unix, target_pointer_width = "64"))]
            Storage::Mapped { .. } => true,
        }
    }

    pub fn len(&self) -> usize {
        self.bytes().len()
    }
}

impl Drop for ByteStore {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Storage::Mapped { ptr, len } = self.storage {
            unsafe {
                sys::munmap(ptr as *mut std::os::raw::c_void, len);
            }
        }
    }
}

/// Element types `WeightBytes` can view. Sealed: exactly the 4-byte
/// little-endian payload scalars the NANOQCK2 format stores.
pub trait Pod: Copy + PartialEq + std::fmt::Debug + Send + Sync + 'static + private::Sealed {
    /// Decode one element from its on-disk little-endian bytes.
    fn from_le(bytes: [u8; 4]) -> Self;
}

mod private {
    pub trait Sealed {}
    impl Sealed for u32 {}
    impl Sealed for f32 {}
}

impl Pod for u32 {
    fn from_le(bytes: [u8; 4]) -> u32 {
        u32::from_le_bytes(bytes)
    }
}

impl Pod for f32 {
    fn from_le(bytes: [u8; 4]) -> f32 {
        f32::from_le_bytes(bytes)
    }
}

enum Repr<T: Pod> {
    Owned(Vec<T>),
    Borrowed {
        store: Arc<ByteStore>,
        /// Byte offset of the first element (4-byte aligned, in bounds).
        offset: usize,
        /// Element count.
        len: usize,
    },
}

/// A weight buffer that is either owned (`Vec<T>`) or a typed borrow into
/// a shared [`ByteStore`] — the Cow abstraction the zero-copy load path
/// threads through `quant::pack` and `quant::scheme`.
pub struct WeightBytes<T: Pod> {
    repr: Repr<T>,
}

impl<T: Pod> WeightBytes<T> {
    /// Borrow `len` elements starting at byte `offset` of `store`.
    ///
    /// Checks bounds and 4-byte alignment; on big-endian targets (or a
    /// misaligned offset, which the NANOQCK2 64-byte payload alignment
    /// rules out for well-formed files) the bytes are decoded into an
    /// owned buffer instead — same values, no borrow.
    pub fn from_store(
        store: Arc<ByteStore>,
        offset: usize,
        len: usize,
    ) -> std::io::Result<WeightBytes<T>> {
        let nbytes = len
            .checked_mul(4)
            .ok_or_else(|| invalid("tensor length overflows"))?;
        let end = offset.checked_add(nbytes).ok_or_else(|| invalid("tensor range overflows"))?;
        if end > store.len() {
            return Err(invalid(format!(
                "tensor range {offset}..{end} exceeds artifact size {}",
                store.len()
            )));
        }
        let base = store.bytes()[offset..].as_ptr();
        let aligned = (base as usize) % std::mem::align_of::<T>() == 0;
        if cfg!(target_endian = "little") && aligned {
            Ok(WeightBytes { repr: Repr::Borrowed { store, offset, len } })
        } else {
            // Portable fallback: decode element-wise.
            let bytes = &store.bytes()[offset..end];
            let owned: Vec<T> = bytes
                .chunks_exact(4)
                .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(WeightBytes { repr: Repr::Owned(owned) })
        }
    }

    /// Whether this buffer borrows from a shared store (zero-copy) rather
    /// than owning its elements.
    pub fn is_borrowed(&self) -> bool {
        matches!(self.repr, Repr::Borrowed { .. })
    }

    /// The elements as a slice (whatever the backing).
    pub fn as_slice(&self) -> &[T] {
        match &self.repr {
            Repr::Owned(v) => v,
            Repr::Borrowed { store, offset, len } => {
                let bytes = &store.bytes()[*offset..*offset + *len * 4];
                // SAFETY: construction checked bounds, 4-byte alignment,
                // and little-endian layout; T is a 4-byte POD. All f32 bit
                // patterns (incl. signaling NaNs) are valid values.
                unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const T, *len) }
            }
        }
    }

    /// Copy into an owned `Vec` (detaching from any mapping).
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }
}

impl<T: Pod> From<Vec<T>> for WeightBytes<T> {
    fn from(v: Vec<T>) -> WeightBytes<T> {
        WeightBytes { repr: Repr::Owned(v) }
    }
}

impl<T: Pod> std::ops::Deref for WeightBytes<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> Clone for WeightBytes<T> {
    fn clone(&self) -> WeightBytes<T> {
        match &self.repr {
            Repr::Owned(v) => WeightBytes { repr: Repr::Owned(v.clone()) },
            // Borrowed clones are an Arc bump, not a copy — cloning a
            // packed layer out of a mapped artifact stays zero-copy.
            Repr::Borrowed { store, offset, len } => WeightBytes {
                repr: Repr::Borrowed { store: store.clone(), offset: *offset, len: *len },
            },
        }
    }
}

impl<T: Pod> PartialEq for WeightBytes<T> {
    fn eq(&self, other: &WeightBytes<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod> std::fmt::Debug for WeightBytes<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let tag = if self.is_borrowed() { "borrowed" } else { "owned" };
        write!(f, "WeightBytes<{tag}>{:?}", self.as_slice())
    }
}

fn invalid<E: ToString>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, bytes: &[u8]) -> String {
        let path = format!("/tmp/nanoquant_bytes_{name}.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        path
    }

    #[test]
    fn heap_and_mmap_see_identical_bytes() {
        let data: Vec<u8> = (0..=255).collect();
        let path = tmp("roundtrip", &data);
        let heap = ByteStore::open(&path, Backing::Heap).unwrap();
        let mapped = ByteStore::open(&path, Backing::Mmap).unwrap();
        assert_eq!(heap.bytes(), &data[..]);
        assert_eq!(mapped.bytes(), &data[..]);
        assert!(!heap.is_mapped());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn weight_bytes_views_decode_u32_and_f32() {
        let mut bytes = Vec::new();
        for w in [0x01020304u32, 0xDEADBEEF, 0] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        for x in [1.5f32, -0.25, f32::MAX] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let path = tmp("views", &bytes);
        for backing in [Backing::Heap, Backing::Mmap] {
            let store = ByteStore::open(&path, backing).unwrap();
            let words: WeightBytes<u32> = WeightBytes::from_store(store.clone(), 0, 3).unwrap();
            assert_eq!(&words[..], &[0x01020304, 0xDEADBEEF, 0]);
            let scales: WeightBytes<f32> = WeightBytes::from_store(store, 12, 3).unwrap();
            assert_eq!(&scales[..], &[1.5, -0.25, f32::MAX]);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn out_of_bounds_views_are_rejected() {
        let path = tmp("oob", &[0u8; 16]);
        let store = ByteStore::open(&path, Backing::Heap).unwrap();
        assert!(WeightBytes::<u32>::from_store(store.clone(), 0, 5).is_err());
        assert!(WeightBytes::<u32>::from_store(store.clone(), 13, 1).is_err());
        assert!(WeightBytes::<u32>::from_store(store.clone(), usize::MAX, 1).is_err());
        assert!(WeightBytes::<u32>::from_store(store, 0, usize::MAX / 2).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn owned_and_borrowed_compare_equal_and_clone_cheaply() {
        let mut bytes = Vec::new();
        for x in [0.5f32, 2.0, -8.25] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let path = tmp("cow", &bytes);
        let store = ByteStore::open(&path, Backing::Mmap).unwrap();
        let borrowed: WeightBytes<f32> = WeightBytes::from_store(store, 0, 3).unwrap();
        let owned: WeightBytes<f32> = vec![0.5f32, 2.0, -8.25].into();
        assert_eq!(borrowed, owned);
        let clone = borrowed.clone();
        assert_eq!(clone.is_borrowed(), borrowed.is_borrowed());
        assert_eq!(clone.to_vec(), vec![0.5, 2.0, -8.25]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mapping_outlives_the_file_handle_and_store_arc_drops() {
        let data = vec![7u8; 4096];
        let path = tmp("lifetime", &data);
        let store = ByteStore::open(&path, Backing::Mmap).unwrap();
        let view: WeightBytes<u32> = WeightBytes::from_store(store.clone(), 0, 1024).unwrap();
        drop(store); // the view's Arc keeps the mapping alive
        assert!(view.iter().all(|&w| w == 0x07070707));
        std::fs::remove_file(&path).ok();
    }
}
