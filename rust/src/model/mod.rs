//! `model` — model artifacts and the multi-model registry.
//!
//! The subsystem between "we can quantize" and "we can serve many
//! scenarios fast":
//!
//! - [`bytes`] — [`ByteStore`] (heap or `mmap` backing for one artifact)
//!   and [`WeightBytes`], the Cow-style buffer that lets `PackedBits`
//!   words and `QuantLinear` scales either own their data (training /
//!   quantization) or borrow it straight out of a mapped artifact
//!   (zero-copy serving).
//! - [`artifact`] — the `NANOQCK2` container: versioned JSON manifest,
//!   64-byte-aligned payloads with explicit per-tensor offsets, trailing
//!   CRC-32. Shared by the FP checkpoints (`nn::checkpoint`) and the
//!   packed serving artifacts below.
//! - [`packed`] — save a frozen [`crate::quant::QuantModel`] as a
//!   `.nqck` serving artifact; load one back as a decode-ready
//!   [`crate::nn::decode::DecodeModel`] whose packed weights borrow from
//!   the mapping. Mmap-loaded and heap-loaded models are byte-identical
//!   in every forward output.
//! - [`store`] — [`ModelStore`], the named-model registry: ref-counted
//!   handles, LRU eviction of idle models under a residency budget, hot
//!   load/unload. The HTTP gateway's multi-model router
//!   (`serve::http::router`) sits on top.

pub mod artifact;
pub mod bytes;
pub mod packed;
pub mod store;

pub use artifact::{Artifact, ArtifactWriter, Crc32, Dtype, TensorEntry};
pub use bytes::{Backing, ByteStore, WeightBytes};
pub use packed::{load_packed_model, save_packed_model, LoadedModel};
pub use store::{ModelHandle, ModelInfo, ModelStore, StoreConfig};
