//! The `NANOQCK2` artifact container — one format for FP checkpoints and
//! packed serving models.
//!
//! ## Layout
//!
//! ```text
//! offset 0   magic  b"NANOQCK2"                                  (8 bytes)
//! offset 8   header_len: u64 LE                                  (8 bytes)
//! offset 16  header: JSON (UTF-8, header_len bytes)
//!            zero padding to the payload base = align64(16 + header_len)
//!            payloads, each starting at a 64-byte-aligned offset,
//!            zero-padded between tensors
//! end - 4    crc: u32 LE — CRC-32 (IEEE) over every preceding byte
//! ```
//!
//! The header is `{"kind": ..., "version": 2, "config"?: ...,
//! "tensors": [{name, dtype, shape, offset, bytes}, ...]}` where `offset`
//! is **relative to the payload base** (so the header's own length never
//! feeds back into the offsets it contains) and every offset is a
//! multiple of 64. Payload scalars are 4-byte little-endian (`f32`, or
//! `u32` sign words for dtype `b1`); 64-byte alignment means a mapped
//! payload can be viewed in place as `&[f32]`/`&[u32]` on any
//! little-endian target — the zero-copy contract `WeightBytes` enforces.
//!
//! `dtype` is `"f32"` (payload = product(shape) × 4 bytes) or `"b1"`
//! (packed ±1 signs: shape is the logical `[rows, cols]`, payload =
//! `rows × ceil(cols/32)` u32 words in the `quant::pack` bit layout).
//!
//! The trailing CRC makes truncation and bit rot detectable without any
//! per-tensor checksums; readers may skip payload verification
//! (`verify_crc = false`) when cold-load latency matters more than
//! integrity — `inspect`, `artifacts-check`, and the test suite always
//! verify.

use super::bytes::{Backing, ByteStore, WeightBytes};
use crate::util::json::{Json, ParseLimits};
use std::collections::HashMap;
use std::io::Write;
use std::sync::Arc;

/// Container magic for the current (v2) format.
pub const MAGIC_V2: &[u8; 8] = b"NANOQCK2";
/// Payload alignment granule.
pub const ALIGN: usize = 64;
/// Largest header a reader will parse (64 MiB covers ~100k-tensor
/// manifests with two orders of magnitude of margin).
pub const MAX_HEADER_BYTES: usize = 64 << 20;

/// Round `x` up to the next multiple of [`ALIGN`].
pub fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

// ---- CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) --------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// Streaming CRC-32 (IEEE) — matches zlib/`binascii.crc32`, which is what
/// the committed golden-fixture generator uses.
#[derive(Clone, Copy)]
pub struct Crc32(u32);

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    pub fn finish(self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

/// CRC-32 of `bytes` in one call.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

// ---- Manifest -----------------------------------------------------------

/// Payload scalar layout of one tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// Dense little-endian `f32`.
    F32,
    /// Packed ±1 sign bits: logical shape `[rows, cols]`, stored as
    /// `rows × ceil(cols/32)` little-endian `u32` words (LSB-first within
    /// a word, zero padding bits — the `quant::pack` layout).
    B1,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::B1 => "b1",
        }
    }

    fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "b1" => Some(Dtype::B1),
            _ => None,
        }
    }

    /// Payload bytes implied by a shape (None: invalid shape for dtype).
    pub fn payload_bytes(&self, shape: &[usize]) -> Option<usize> {
        match self {
            Dtype::F32 => {
                let mut n = 1usize;
                for &d in shape {
                    n = n.checked_mul(d)?;
                }
                n.checked_mul(4)
            }
            Dtype::B1 => {
                if shape.len() != 2 {
                    return None;
                }
                shape[0].checked_mul(shape[1].div_ceil(32))?.checked_mul(4)
            }
        }
    }
}

/// One manifest entry (offsets absolute within the file once parsed).
#[derive(Clone, Debug)]
pub struct TensorEntry {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// Absolute byte offset of the payload within the artifact.
    pub offset: usize,
    /// Payload length in bytes (excludes inter-tensor padding).
    pub bytes: usize,
}

// ---- Writer -------------------------------------------------------------

enum PayloadRef<'a> {
    F32(&'a [f32]),
    U32(&'a [u32]),
}

/// Builder for one NANOQCK2 file: register tensors (borrowed — nothing is
/// copied until [`ArtifactWriter::write`]), attach header metadata, write.
pub struct ArtifactWriter<'a> {
    kind: &'a str,
    meta: Vec<(&'a str, Json)>,
    tensors: Vec<(String, Dtype, Vec<usize>, PayloadRef<'a>)>,
}

impl<'a> ArtifactWriter<'a> {
    /// A writer for an artifact of the given `kind` (free-form tag the
    /// readers dispatch on, e.g. `"fp-checkpoint"` or `"packed-model"`).
    pub fn new(kind: &'a str) -> ArtifactWriter<'a> {
        ArtifactWriter { kind, meta: Vec::new(), tensors: Vec::new() }
    }

    /// Attach a top-level header field (e.g. `"config"`).
    pub fn meta(&mut self, key: &'a str, val: Json) {
        self.meta.push((key, val));
    }

    /// Register a dense f32 tensor. `data.len()` must equal the shape
    /// product.
    pub fn push_f32(&mut self, name: &str, shape: &[usize], data: &'a [f32]) {
        assert_eq!(
            data.len() * 4,
            Dtype::F32.payload_bytes(shape).expect("f32 shape"),
            "push_f32 {name}: data length vs shape"
        );
        self.tensors.push((name.to_string(), Dtype::F32, shape.to_vec(), PayloadRef::F32(data)));
    }

    /// Register a packed ±1 bit tensor with logical shape `[rows, cols]`;
    /// `words` is the row-major word buffer (`rows × ceil(cols/32)`).
    pub fn push_bits(&mut self, name: &str, rows: usize, cols: usize, words: &'a [u32]) {
        assert_eq!(
            words.len(),
            rows * cols.div_ceil(32),
            "push_bits {name}: word count vs [rows, cols]"
        );
        self.tensors.push((name.to_string(), Dtype::B1, vec![rows, cols], PayloadRef::U32(words)));
    }

    /// Serialize to `path` (parent directories created).
    ///
    /// The write is atomic-by-rename: bytes go to a temporary sibling
    /// file which replaces `path` only after a successful flush. An
    /// in-place truncate would mutate pages under any live `mmap` of the
    /// previous artifact (the `ByteStore` soundness contract) and a
    /// mid-write crash would destroy the old good file; the rename does
    /// neither — existing mappings keep the old inode alive.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = format!("{path}.tmp.{}", std::process::id());
        match self.write_to(&tmp).and_then(|()| std::fs::rename(&tmp, path)) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                Err(e)
            }
        }
    }

    fn write_to(&self, path: &str) -> std::io::Result<()> {
        // Relative offsets: each payload starts at the next 64-byte
        // boundary past the previous one. Independent of the header size.
        let mut manifest = Vec::with_capacity(self.tensors.len());
        let mut cursor = 0usize;
        for (name, dtype, shape, payload) in &self.tensors {
            let offset = align_up(cursor);
            let bytes = match payload {
                PayloadRef::F32(d) => d.len() * 4,
                PayloadRef::U32(d) => d.len() * 4,
            };
            manifest.push(
                Json::obj()
                    .set("name", name.as_str())
                    .set("dtype", dtype.name())
                    .set("shape", shape.clone())
                    .set("offset", offset)
                    .set("bytes", bytes),
            );
            cursor = offset + bytes;
        }
        let mut header = Json::obj().set("kind", self.kind).set("version", 2usize);
        for (key, val) in &self.meta {
            header.insert(key, val.clone());
        }
        let header = header.set("tensors", Json::Arr(manifest)).to_string();

        let file = std::fs::File::create(path)?;
        let mut w = CrcWriter { inner: std::io::BufWriter::new(file), crc: Crc32::new() };
        w.write_all(MAGIC_V2)?;
        w.write_all(&(header.len() as u64).to_le_bytes())?;
        w.write_all(header.as_bytes())?;
        let base = align_up(16 + header.len());
        w.pad(base - (16 + header.len()))?;
        let mut cursor = 0usize;
        for (_, _, _, payload) in &self.tensors {
            let offset = align_up(cursor);
            w.pad(offset - cursor)?;
            cursor = offset;
            cursor += match payload {
                PayloadRef::F32(d) => {
                    w.write_scalars(d.iter().map(|x| x.to_le_bytes()))?;
                    d.len() * 4
                }
                PayloadRef::U32(d) => {
                    w.write_scalars(d.iter().map(|x| x.to_le_bytes()))?;
                    d.len() * 4
                }
            };
        }
        let crc = w.crc.finish();
        // The CRC itself is excluded from the checksum.
        w.inner.write_all(&crc.to_le_bytes())?;
        w.inner.flush()
    }
}

struct CrcWriter {
    inner: std::io::BufWriter<std::fs::File>,
    crc: Crc32,
}

impl CrcWriter {
    fn write_all(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.crc.update(bytes);
        self.inner.write_all(bytes)
    }

    fn pad(&mut self, n: usize) -> std::io::Result<()> {
        const ZEROS: [u8; ALIGN] = [0u8; ALIGN];
        debug_assert!(n < ALIGN);
        self.write_all(&ZEROS[..n])
    }

    /// Write 4-byte scalars through a chunk buffer (one syscall-sized
    /// memcpy instead of per-element `write_all`).
    fn write_scalars(&mut self, scalars: impl Iterator<Item = [u8; 4]>) -> std::io::Result<()> {
        let mut buf = [0u8; 16 << 10];
        let mut fill = 0usize;
        for s in scalars {
            buf[fill..fill + 4].copy_from_slice(&s);
            fill += 4;
            if fill == buf.len() {
                self.write_all(&buf)?;
                fill = 0;
            }
        }
        if fill > 0 {
            self.write_all(&buf[..fill])?;
        }
        Ok(())
    }
}

// ---- Reader -------------------------------------------------------------

/// A parsed, validated NANOQCK2 artifact: the shared byte store plus the
/// decoded manifest. Tensor views borrow from the store (zero-copy on
/// mapped little-endian loads).
pub struct Artifact {
    store: Arc<ByteStore>,
    header: Json,
    kind: String,
    tensors: Vec<TensorEntry>,
    /// Name → manifest position, so per-tensor lookups are O(1) — a
    /// packed model does ~13 lookups per linear, and a linear scan would
    /// make the cold load quadratic in tensor count.
    index: HashMap<String, usize>,
}

impl Artifact {
    /// Open and validate `path`. Structural checks (magic, header JSON,
    /// manifest bounds/alignment/size consistency) always run;
    /// `verify_crc` additionally streams the whole file through the
    /// trailing CRC — skip it only when cold-load latency matters more
    /// than integrity.
    pub fn open(path: &str, backing: Backing, verify_crc: bool) -> std::io::Result<Artifact> {
        let store = ByteStore::open(path, backing)?;
        let bytes = store.bytes();
        if bytes.len() < 16 + 4 {
            return Err(invalid(format!("artifact too short ({} bytes)", bytes.len())));
        }
        if &bytes[..8] != MAGIC_V2 {
            return Err(invalid(format!(
                "bad magic {:?} (expected NANOQCK2)",
                String::from_utf8_lossy(&bytes[..8.min(bytes.len())])
            )));
        }
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        if header_len as usize > MAX_HEADER_BYTES {
            return Err(invalid(format!("header length {header_len} exceeds the reader cap")));
        }
        let header_len = header_len as usize;
        let payload_base = align_up(16 + header_len);
        if payload_base + 4 > bytes.len() {
            return Err(invalid(format!(
                "header length {header_len} exceeds the {}-byte file",
                bytes.len()
            )));
        }
        if verify_crc {
            let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
            let computed = crc32(&bytes[..bytes.len() - 4]);
            if stored != computed {
                return Err(invalid(format!(
                    "CRC mismatch: stored {stored:#010x}, computed {computed:#010x} \
                     (truncated or corrupt artifact)"
                )));
            }
        }
        let text = std::str::from_utf8(&bytes[16..16 + header_len])
            .map_err(|_| invalid("header is not UTF-8"))?;
        let limits = ParseLimits { max_bytes: MAX_HEADER_BYTES, max_depth: 16 };
        let header = Json::parse_with_limits(text, limits)
            .map_err(|e| invalid(format!("header JSON: {e}")))?;
        let kind = header
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| invalid("header missing \"kind\""))?
            .to_string();
        match header.get("version").and_then(Json::as_usize) {
            Some(2) => {}
            Some(v) => return Err(invalid(format!("unsupported artifact version {v}"))),
            None => return Err(invalid("header missing \"version\"")),
        }
        let manifest = header
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| invalid("header missing \"tensors\" array"))?;
        let payload_end = bytes.len() - 4;
        let mut tensors = Vec::with_capacity(manifest.len());
        let mut index = HashMap::with_capacity(manifest.len());
        for (i, entry) in manifest.iter().enumerate() {
            let tensor = parse_entry(entry, i, payload_base, payload_end)?;
            if index.insert(tensor.name.clone(), i).is_some() {
                return Err(invalid(format!("duplicate tensor name {:?}", tensor.name)));
            }
            tensors.push(tensor);
        }
        Ok(Artifact { store, header, kind, tensors, index })
    }

    /// The artifact kind tag (`"fp-checkpoint"`, `"packed-model"`, ...).
    pub fn kind(&self) -> &str {
        &self.kind
    }

    /// The raw parsed header (for `config` and other metadata fields).
    pub fn header(&self) -> &Json {
        &self.header
    }

    /// Whether the backing is a file mapping.
    pub fn is_mapped(&self) -> bool {
        self.store.is_mapped()
    }

    /// Total artifact size in bytes.
    pub fn file_bytes(&self) -> usize {
        self.store.len()
    }

    /// Manifest entries in file order.
    pub fn tensors(&self) -> &[TensorEntry] {
        &self.tensors
    }

    /// Manifest entry by name (O(1) via the name index).
    pub fn entry(&self, name: &str) -> std::io::Result<&TensorEntry> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| invalid(format!("artifact has no tensor {name:?}")))
    }

    /// Borrow an f32 tensor's payload (zero-copy on mapped stores).
    pub fn f32_view(&self, name: &str) -> std::io::Result<WeightBytes<f32>> {
        let e = self.entry(name)?;
        if e.dtype != Dtype::F32 {
            return Err(invalid(format!("tensor {name:?} is {}, not f32", e.dtype.name())));
        }
        WeightBytes::from_store(self.store.clone(), e.offset, e.bytes / 4)
    }

    /// Borrow a b1 tensor's packed words (zero-copy on mapped stores).
    pub fn bits_view(&self, name: &str) -> std::io::Result<WeightBytes<u32>> {
        let e = self.entry(name)?;
        if e.dtype != Dtype::B1 {
            return Err(invalid(format!("tensor {name:?} is {}, not b1", e.dtype.name())));
        }
        WeightBytes::from_store(self.store.clone(), e.offset, e.bytes / 4)
    }

    /// Copy an f32 tensor out (for heap consumers like `Tensor`).
    pub fn f32_vec(&self, name: &str) -> std::io::Result<Vec<f32>> {
        Ok(self.f32_view(name)?.to_vec())
    }
}

fn parse_entry(
    entry: &Json,
    i: usize,
    payload_base: usize,
    payload_end: usize,
) -> std::io::Result<TensorEntry> {
    let name = entry
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| invalid(format!("tensors[{i}] missing \"name\"")))?
        .to_string();
    let ctx = |field: &str| invalid(format!("tensor {name:?}: missing or invalid \"{field}\""));
    let dtype = entry
        .get("dtype")
        .and_then(Json::as_str)
        .and_then(Dtype::parse)
        .ok_or_else(|| ctx("dtype"))?;
    let shape: Vec<usize> = entry
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| ctx("shape"))?
        .iter()
        .map(|v| v.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize))
        .collect::<Option<Vec<usize>>>()
        .ok_or_else(|| ctx("shape"))?;
    let rel = entry
        .get("offset")
        .and_then(Json::as_f64)
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as usize)
        .ok_or_else(|| ctx("offset"))?;
    let bytes = entry
        .get("bytes")
        .and_then(Json::as_f64)
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as usize)
        .ok_or_else(|| ctx("bytes"))?;
    let expect = dtype
        .payload_bytes(&shape)
        .ok_or_else(|| invalid(format!("tensor {name:?}: shape {shape:?} invalid for dtype")))?;
    if expect != bytes {
        return Err(invalid(format!(
            "tensor {name:?}: manifest bytes {bytes} != {expect} implied by dtype/shape"
        )));
    }
    if rel % ALIGN != 0 {
        return Err(invalid(format!("tensor {name:?}: offset {rel} not {ALIGN}-byte aligned")));
    }
    let offset = payload_base.checked_add(rel).ok_or_else(|| ctx("offset"))?;
    let end = offset.checked_add(bytes).ok_or_else(|| ctx("bytes"))?;
    if end > payload_end {
        return Err(invalid(format!(
            "tensor {name:?}: payload {offset}..{end} exceeds artifact payload region \
             (file truncated?)"
        )));
    }
    Ok(TensorEntry { name, dtype, shape, offset, bytes })
}

fn invalid<E: ToString>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard IEEE test vector ("123456789" -> 0xCBF43926), matching
        // zlib / Python binascii.crc32 (the golden-fixture generator).
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
        let mut streaming = Crc32::new();
        streaming.update(b"1234");
        streaming.update(b"56789");
        assert_eq!(streaming.finish(), 0xCBF43926);
    }

    fn sample_path(name: &str) -> String {
        format!("/tmp/nanoquant_artifact_{name}.nqck")
    }

    fn write_sample(path: &str) -> (Vec<f32>, Vec<u32>) {
        let f: Vec<f32> = (0..33).map(|i| i as f32 * 0.25 - 2.0).collect();
        let words: Vec<u32> = (0..6).map(|i| (i as u32 * 5 + 3) & 0xFFFF).collect();
        let mut w = ArtifactWriter::new("test-artifact");
        w.meta("config", Json::obj().set("d", 33usize));
        w.push_f32("scales", &[33], &f);
        w.push_bits("signs", 6, 16, &words);
        w.write(path).unwrap();
        (f, words)
    }

    #[test]
    fn roundtrip_heap_and_mmap_with_alignment_and_crc() {
        let path = sample_path("roundtrip");
        let (f, words) = write_sample(&path);
        for backing in [Backing::Heap, Backing::Mmap] {
            let a = Artifact::open(&path, backing, true).unwrap();
            assert_eq!(a.kind(), "test-artifact");
            assert_eq!(
                a.header().get("config").and_then(|c| c.get("d")).and_then(Json::as_usize),
                Some(33)
            );
            for t in a.tensors() {
                assert_eq!(t.offset % ALIGN, 0, "{} misaligned", t.name);
            }
            assert_eq!(a.f32_view("scales").unwrap().to_vec(), f);
            assert_eq!(a.bits_view("signs").unwrap().to_vec(), words);
            assert_eq!(a.entry("signs").unwrap().shape, vec![6, 16]);
            // Dtype confusion is rejected.
            assert!(a.f32_view("signs").is_err());
            assert!(a.bits_view("scales").is_err());
            assert!(a.entry("nope").is_err());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let path = sample_path("corrupt");
        write_sample(&path);
        let good = std::fs::read(&path).unwrap();

        // Flip one payload byte: structural checks pass, CRC catches it.
        let mut bad = good.clone();
        let last = bad.len() - 5;
        bad[last] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        assert!(Artifact::open(&path, Backing::Heap, true).is_err());
        assert!(
            Artifact::open(&path, Backing::Heap, false).is_ok(),
            "verify_crc=false must skip payload verification"
        );

        // Truncation: manifest range check fires even without CRC.
        std::fs::write(&path, &good[..good.len() - 40]).unwrap();
        assert!(Artifact::open(&path, Backing::Heap, false).is_err());

        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        assert!(Artifact::open(&path, Backing::Heap, false).is_err());

        // Hostile header length: must error, not allocate/scan unbounded.
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        assert!(Artifact::open(&path, Backing::Heap, false).is_err());

        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_manifest_roundtrips() {
        let path = sample_path("empty");
        ArtifactWriter::new("empty").write(&path).unwrap();
        let a = Artifact::open(&path, Backing::Heap, true).unwrap();
        assert_eq!(a.tensors().len(), 0);
        assert_eq!(a.kind(), "empty");
        std::fs::remove_file(&path).ok();
    }
}
