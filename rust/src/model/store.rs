//! [`ModelStore`] — the named-model registry behind the multi-model
//! gateway.
//!
//! The store owns up to [`StoreConfig::max_resident`] decode-ready models
//! keyed by name. Callers hold [`ModelHandle`]s: cloning a handle bumps
//! the entry's ref count, dropping it decrements and stamps a
//! last-used tick. When a load pushes the registry over budget, **idle**
//! entries (ref count zero) are evicted least-recently-used first; pinned
//! entries are never evicted, so the registry can transiently exceed its
//! budget rather than tear weights out from under a serving engine.
//!
//! Eviction and [`ModelStore::unload`] only remove the registry entry —
//! the model itself is an `Arc<DecodeModel>`, and any engine still
//! holding one (and through it the mmap'd artifact's `Arc<ByteStore>`)
//! keeps the weights and the mapping alive until it drains. Borrowed
//! weights can therefore never dangle, whatever the registry does; the
//! gateway's unload endpoint still drains in-flight requests first so
//! memory is actually returned when the call reports success.

use super::bytes::Backing;
use super::packed::load_packed_model;
use crate::nn::decode::DecodeModel;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Registry configuration.
#[derive(Clone, Copy, Debug)]
pub struct StoreConfig {
    /// Resident-model budget. Loads beyond it evict idle models LRU-first
    /// (pinned models are never evicted, so the budget is soft under
    /// all-pinned pressure).
    pub max_resident: usize,
    /// Verify the trailing CRC on every artifact load.
    pub verify_crc: bool,
}

impl Default for StoreConfig {
    fn default() -> StoreConfig {
        StoreConfig { max_resident: 4, verify_crc: true }
    }
}

struct Entry {
    model: Arc<DecodeModel>,
    path: Option<String>,
    file_bytes: usize,
    mapped: bool,
    refs: usize,
    last_used: u64,
}

struct Inner {
    entries: HashMap<String, Entry>,
    /// Monotonic use counter (LRU ordering without a clock).
    tick: u64,
    evictions: u64,
}

impl Inner {
    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Evict idle LRU entries until the budget holds (or only pinned
    /// entries remain).
    fn evict_over_budget(&mut self, max_resident: usize) {
        while self.entries.len() > max_resident {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.refs == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(name, _)| name.clone());
            match victim {
                Some(name) => {
                    self.entries.remove(&name);
                    self.evictions += 1;
                }
                None => break,
            }
        }
    }
}

/// Metadata snapshot of one resident model (see [`ModelStore::list`]).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    /// Effective weight bytes of the decode model.
    pub weight_bytes: usize,
    /// Artifact size on disk (0 for models inserted in process).
    pub file_bytes: usize,
    /// Whether the packed weights borrow from a file mapping.
    pub mapped: bool,
    /// Outstanding handles.
    pub refs: usize,
    /// Source artifact path, if loaded from disk.
    pub path: Option<String>,
}

/// The registry. Cheap to clone (shared state behind an `Arc`).
#[derive(Clone)]
pub struct ModelStore {
    cfg: StoreConfig,
    inner: Arc<Mutex<Inner>>,
}

/// A ref-counted pin on one resident model. Holds the `Arc<DecodeModel>`
/// directly, so the model stays usable even if the registry entry is
/// evicted or unloaded while the handle lives.
pub struct ModelHandle {
    name: String,
    model: Arc<DecodeModel>,
    mapped: bool,
    inner: Arc<Mutex<Inner>>,
}

impl ModelHandle {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The pinned model.
    pub fn model(&self) -> &Arc<DecodeModel> {
        &self.model
    }

    /// Whether the pinned model's packed weights borrow from a file
    /// mapping (zero-copy) rather than a heap buffer.
    pub fn mapped(&self) -> bool {
        self.mapped
    }
}

impl Clone for ModelHandle {
    fn clone(&self) -> ModelHandle {
        let mut inner = self.inner.lock().unwrap();
        // Only count against the entry if it is still *this* model — a
        // same-named reload must not inherit our pin.
        if let Some(e) = inner.entries.get_mut(&self.name) {
            if Arc::ptr_eq(&e.model, &self.model) {
                e.refs += 1;
            }
        }
        drop(inner);
        ModelHandle {
            name: self.name.clone(),
            model: self.model.clone(),
            mapped: self.mapped,
            inner: self.inner.clone(),
        }
    }
}

impl Drop for ModelHandle {
    fn drop(&mut self) {
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.touch();
        if let Some(e) = inner.entries.get_mut(&self.name) {
            if Arc::ptr_eq(&e.model, &self.model) {
                e.refs = e.refs.saturating_sub(1);
                e.last_used = tick;
            }
        }
    }
}

impl ModelStore {
    pub fn new(cfg: StoreConfig) -> ModelStore {
        ModelStore {
            cfg,
            inner: Arc::new(Mutex::new(Inner {
                entries: HashMap::new(),
                tick: 0,
                evictions: 0,
            })),
        }
    }

    fn handle(&self, name: &str, model: Arc<DecodeModel>, mapped: bool) -> ModelHandle {
        ModelHandle { name: name.to_string(), model, mapped, inner: self.inner.clone() }
    }

    /// Register an in-process model (e.g. the gateway's default dense
    /// engine), replacing any same-named entry, and pin it.
    pub fn insert(&self, name: &str, model: DecodeModel) -> ModelHandle {
        let model = Arc::new(model);
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.touch();
        inner.entries.insert(
            name.to_string(),
            Entry {
                model: model.clone(),
                path: None,
                file_bytes: 0,
                mapped: false,
                refs: 1,
                last_used: tick,
            },
        );
        inner.evict_over_budget(self.cfg.max_resident);
        drop(inner);
        ModelHandle { name: name.to_string(), model, mapped: false, inner: self.inner.clone() }
    }

    /// Load (or re-use) the named model and pin it.
    ///
    /// A resident entry whose source is the **same path** is a cache hit
    /// — the artifact is not re-read (`backing` is then ignored). A
    /// resident entry from a *different* path (or an in-process
    /// [`ModelStore::insert`]) is an `AlreadyExists` error: silently
    /// serving weights other than the ones the caller named would be a
    /// lie — unload first to swap. Cold loads read the artifact *outside*
    /// the registry lock (loads of different models proceed
    /// concurrently), insert, and enforce the budget by evicting idle LRU
    /// entries.
    pub fn load(&self, name: &str, path: &str, backing: Backing) -> std::io::Result<ModelHandle> {
        let cache_hit = |e: &mut Entry, tick: u64| -> std::io::Result<(Arc<DecodeModel>, bool)> {
            if e.path.as_deref() != Some(path) {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::AlreadyExists,
                    format!(
                        "model {name:?} is already resident from {:?}; unload it before \
                         loading {path:?}",
                        e.path.as_deref().unwrap_or("(in-process)")
                    ),
                ));
            }
            e.refs += 1;
            e.last_used = tick;
            Ok((e.model.clone(), e.mapped))
        };
        {
            let mut inner = self.inner.lock().unwrap();
            let tick = inner.touch();
            if let Some(e) = inner.entries.get_mut(name) {
                let (model, mapped) = cache_hit(e, tick)?;
                drop(inner);
                return Ok(self.handle(name, model, mapped));
            }
        }
        let loaded = load_packed_model(path, backing, self.cfg.verify_crc)?;
        let model = Arc::new(loaded.model);
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.touch();
        if let Some(e) = inner.entries.get_mut(name) {
            // Raced with another load of the same name: keep theirs iff
            // it came from the same artifact (path mismatch errors).
            let (model, mapped) = cache_hit(e, tick)?;
            drop(inner);
            return Ok(self.handle(name, model, mapped));
        }
        let mapped = loaded.mapped;
        inner.entries.insert(
            name.to_string(),
            Entry {
                model: model.clone(),
                path: Some(path.to_string()),
                file_bytes: loaded.file_bytes,
                mapped,
                refs: 1,
                last_used: tick,
            },
        );
        inner.evict_over_budget(self.cfg.max_resident);
        drop(inner);
        Ok(self.handle(name, model, mapped))
    }

    /// Pin a resident model by name (None if not resident).
    pub fn get(&self, name: &str) -> Option<ModelHandle> {
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.touch();
        let e = inner.entries.get_mut(name)?;
        e.refs += 1;
        e.last_used = tick;
        let (model, mapped) = (e.model.clone(), e.mapped);
        drop(inner);
        Some(self.handle(name, model, mapped))
    }

    /// Remove the named entry from the registry (true if it was
    /// resident). Outstanding handles keep their model alive; the weights
    /// and any file mapping are freed when the last one drops.
    pub fn unload(&self, name: &str) -> bool {
        self.inner.lock().unwrap().entries.remove(name).is_some()
    }

    /// Snapshot of every resident model, sorted by name.
    pub fn list(&self) -> Vec<ModelInfo> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<ModelInfo> = inner
            .entries
            .iter()
            .map(|(name, e)| ModelInfo {
                name: name.clone(),
                weight_bytes: e.model.weight_bytes(),
                file_bytes: e.file_bytes,
                mapped: e.mapped,
                refs: e.refs,
                path: e.path.clone(),
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Resident entries right now.
    pub fn resident(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Idle evictions performed so far.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::packed::save_packed_model;
    use crate::nn::decode::generate_greedy;
    use crate::quant::Engine;

    fn store(max_resident: usize) -> ModelStore {
        ModelStore::new(StoreConfig { max_resident, ..Default::default() })
    }

    fn save_fixture(name: &str, seed: u64) -> String {
        let qm = crate::model::packed::quantized_zoo_model(seed);
        let path = format!("/tmp/nanoquant_test_store_{name}.nqck");
        save_packed_model(&path, &qm).unwrap();
        path
    }

    #[test]
    fn load_is_cached_and_serves_the_same_weights() {
        let path = save_fixture("cache", 1);
        let store = store(4);
        let a = store.load("m", &path, Backing::Mmap).unwrap();
        let b = store.load("m", &path, Backing::Heap).unwrap();
        assert!(Arc::ptr_eq(a.model(), b.model()), "same-path cache hit must not reload");
        // Same name, different source: refused rather than silently
        // serving the resident weights under the new path's flag.
        let err = store.load("m", "/some/other/artifact.nqck", Backing::Heap).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::AlreadyExists, "{err}");
        assert_eq!(store.resident(), 1);
        let info = &store.list()[0];
        assert_eq!(info.refs, 2);
        assert!(info.file_bytes > 0);
        drop(a);
        drop(b);
        assert_eq!(store.list()[0].refs, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lru_eviction_under_a_small_budget_skips_pinned_models() {
        let paths: Vec<String> =
            (0..4).map(|i| save_fixture(&format!("lru{i}"), 10 + i as u64)).collect();
        let store = store(2);
        let pin_a = store.load("a", &paths[0], Backing::Heap).unwrap();
        {
            let _b = store.load("b", &paths[1], Backing::Heap).unwrap();
        } // b idle now
        // Loading c exceeds the budget: b (idle LRU) is evicted, a is
        // pinned and survives.
        let _pin_c = store.load("c", &paths[2], Backing::Heap).unwrap();
        assert_eq!(store.resident(), 2);
        assert_eq!(store.evictions(), 1);
        assert!(store.get("b").is_none(), "idle LRU entry must be evicted");
        assert!(store.get("a").is_some(), "pinned entry must survive");
        // All pinned + over budget: nothing evictable, budget is soft.
        let _pin_d = store.load("d", &paths[3], Backing::Heap).unwrap();
        assert_eq!(store.resident(), 3, "pinned entries are never evicted");
        drop(pin_a);
        // The evicted model still works through a surviving handle even
        // after unload (Arc keeps weights + mapping alive).
        let handle = store.get("c").unwrap();
        assert!(store.unload("c"));
        assert!(store.get("c").is_none());
        let toks = generate_greedy(handle.model(), &[1, 2, 3], 4, &[]);
        assert_eq!(toks.len(), 4);
        for p in &paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn reload_after_unload_reads_the_artifact_again() {
        let path = save_fixture("reload", 3);
        let store = store(4);
        let first = store.load("m", &path, Backing::Heap).unwrap();
        let reference = {
            let qm = crate::model::packed::quantized_zoo_model(3);
            let dm = qm.to_decode_model(Engine::Packed);
            generate_greedy(&dm, &[5, 6, 7], 5, &[])
        };
        assert_eq!(generate_greedy(first.model(), &[5, 6, 7], 5, &[]), reference);
        store.unload("m");
        let second = store.load("m", &path, Backing::Mmap).unwrap();
        assert!(!Arc::ptr_eq(first.model(), second.model()));
        assert_eq!(generate_greedy(second.model(), &[5, 6, 7], 5, &[]), reference);
        // A stale handle's drop must not corrupt the new entry's refcount.
        drop(first);
        assert_eq!(store.list()[0].refs, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn insert_replaces_and_clone_tracks_refs() {
        let cfg = crate::nn::family_config("l2", "xs");
        let mut rng = crate::util::rng::Rng::new(0);
        let params = crate::nn::model::ModelParams::init(&cfg, &mut rng);
        let store = store(4);
        let h = store.insert("default", crate::nn::decode::dense_decode_model(&params));
        let h2 = h.clone();
        assert_eq!(store.list()[0].refs, 2);
        drop(h);
        drop(h2);
        assert_eq!(store.list()[0].refs, 0);
    }
}
