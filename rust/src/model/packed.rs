//! Packed serving artifacts (`.nqck`): a frozen [`QuantModel`] on disk,
//! loadable straight into a decode-ready [`DecodeModel`].
//!
//! The artifact is a NANOQCK2 container of kind `"packed-model"`. FP
//! parts (embeddings, norms, untied head — the parts the paper keeps at
//! full precision, Appendix F.6) are `f32` tensors; every quantized
//! decoder linear stores its two packed sign factors as `b1` tensors plus
//! two `f32` scale vectors:
//!
//! ```text
//! embed                      f32 [vocab, d]
//! b{i}.ln1 / b{i}.ln2        f32 [d]
//! b{i}.{wq,...}.u            b1  [n, r]      packed sign(U)
//! b{i}.{wq,...}.vt           b1  [r, m]      packed sign(V)ᵀ
//! b{i}.{wq,...}.s1 / .s2     f32 [n] / [m]   channel scales
//! b{i}.{wq,...}.w            f32 [n, m]      (only for unquantized layers)
//! ln_f                       f32 [d]
//! head                       f32 [vocab, d]  (untied models only)
//! ```
//!
//! On load, the `b1` words and the scale vectors become [`WeightBytes`]
//! views into the artifact's [`ByteStore`] — with `Backing::Mmap` that is
//! a zero-copy borrow of the mapping (the 64-byte payload alignment
//! guarantees the in-place `&[u32]`/`&[f32]` casts are aligned), so a
//! loaded model's packed weights add no resident memory beyond the page
//! cache. FP parts are materialized into heap `Tensor`s (they feed the
//! shared `nn` forward, which owns its data). Heap- and mmap-loaded
//! models read identical bytes, so their forward outputs — and therefore
//! greedy generations — are bit-for-bit equal; the test suite asserts
//! this with `==`.
//!
//! [`QuantModel`]: crate::quant::QuantModel
//! [`DecodeModel`]: crate::nn::decode::DecodeModel
//! [`WeightBytes`]: crate::model::bytes::WeightBytes
//! [`ByteStore`]: crate::model::bytes::ByteStore

use super::artifact::{Artifact, ArtifactWriter};
use super::bytes::Backing;
use crate::nn::checkpoint::{cfg_from_json, cfg_to_json};
use crate::nn::decode::{DecodeBlock, DecodeModel, MatVec};
use crate::nn::model::LayerKind;
use crate::nn::LayerId;
use crate::quant::kernels::PackedLinear;
use crate::quant::pack::PackedBits;
use crate::quant::scheme::QuantLinear;
use crate::quant::QuantModel;
use crate::tensor::Tensor;
use std::collections::BTreeMap;

/// Artifact kind tag for packed serving models.
pub const KIND_PACKED: &str = "packed-model";

/// Short layer names, matching the checkpoint convention (`b0.wq`, ...).
fn short(kind: LayerKind) -> &'static str {
    match kind {
        LayerKind::Q => "wq",
        LayerKind::K => "wk",
        LayerKind::V => "wv",
        LayerKind::O => "wo",
        LayerKind::Gate => "wg",
        LayerKind::Up => "wu",
        LayerKind::Down => "wd",
    }
}

/// Save `qm` as a packed serving artifact. Quantized layers are written
/// in their packed form with the *current* scales (exactly what
/// [`QuantModel::to_decode_model`] would serve); unquantized decoder
/// linears fall back to dense `f32`.
///
/// [`QuantModel::to_decode_model`]: crate::quant::QuantModel::to_decode_model
pub fn save_packed_model(path: &str, qm: &QuantModel) -> std::io::Result<()> {
    let p = &qm.params;
    // Freeze the packed forms first; the writer borrows from them.
    let frozen: BTreeMap<LayerId, QuantLinear> =
        qm.layers.iter().map(|(id, q)| (*id, q.packed())).collect();

    let mut w = ArtifactWriter::new(KIND_PACKED);
    w.meta("config", cfg_to_json(&p.cfg));
    w.push_f32("embed", &p.embed.shape, &p.embed.data);
    for (bi, b) in p.blocks.iter().enumerate() {
        w.push_f32(&format!("b{bi}.ln1"), &[b.ln1.len()], &b.ln1);
        for kind in LayerKind::ALL {
            let base = format!("b{bi}.{}", short(kind));
            match frozen.get(&LayerId { block: bi, kind }) {
                Some(q) => {
                    w.push_bits(&format!("{base}.u"), q.u.rows, q.u.cols, &q.u.words);
                    w.push_bits(&format!("{base}.vt"), q.vt.rows, q.vt.cols, &q.vt.words);
                    w.push_f32(&format!("{base}.s1"), &[q.s1.len()], &q.s1);
                    w.push_f32(&format!("{base}.s2"), &[q.s2.len()], &q.s2);
                }
                None => {
                    let t = b.linear(kind);
                    w.push_f32(&format!("{base}.w"), &t.shape, &t.data);
                }
            }
        }
        w.push_f32(&format!("b{bi}.ln2"), &[b.ln2.len()], &b.ln2);
    }
    w.push_f32("ln_f", &[p.ln_f.len()], &p.ln_f);
    if let Some(h) = &p.head {
        w.push_f32("head", &h.shape, &h.data);
    }
    w.write(path)
}

/// A packed model loaded from disk, plus load-path metadata.
pub struct LoadedModel {
    /// Decode-ready model (packed engines for quantized layers, dense for
    /// the rest).
    pub model: DecodeModel,
    /// Total artifact size on disk.
    pub file_bytes: usize,
    /// Whether the packed weights borrow from a file mapping (zero-copy)
    /// rather than a heap buffer.
    pub mapped: bool,
    /// Decoder linears served by the packed kernels.
    pub quantized_layers: usize,
}

/// Load a packed serving artifact.
///
/// `backing` selects zero-copy `mmap` or a heap read; outputs are
/// bit-identical either way. `verify_crc` streams the file through the
/// trailing CRC before any tensor is touched (recommended everywhere
/// except latency-critical cold starts on trusted storage).
pub fn load_packed_model(
    path: &str,
    backing: Backing,
    verify_crc: bool,
) -> std::io::Result<LoadedModel> {
    let a = Artifact::open(path, backing, verify_crc)?;
    if a.kind() != KIND_PACKED {
        return Err(invalid(format!(
            "artifact kind {:?} is not a packed model (expected {KIND_PACKED:?})",
            a.kind()
        )));
    }
    let cfg = cfg_from_json(
        a.header().get("config").ok_or_else(|| invalid("header missing \"config\""))?,
    )?;
    let embed = tensor_of(&a, "embed")?;
    if embed.shape != [cfg.vocab, cfg.d_model] {
        return Err(invalid(format!(
            "embed shape {:?} does not match config [{}, {}]",
            embed.shape, cfg.vocab, cfg.d_model
        )));
    }
    // Bound the layer count by the manifest before any per-layer work: a
    // hostile header must error, not abort in the allocator (each layer
    // needs at least ten tensors, so this is a generous bound).
    if cfg.n_layers > a.tensors().len() {
        return Err(invalid(format!(
            "config claims {} layers but the manifest has only {} tensors",
            cfg.n_layers,
            a.tensors().len()
        )));
    }
    let mut quantized_layers = 0usize;
    let mut blocks = Vec::new();
    for bi in 0..cfg.n_layers {
        let mut lin = |kind: LayerKind| -> std::io::Result<Box<dyn MatVec>> {
            let base = format!("b{bi}.{}", short(kind));
            let (n, m) = expected_dims(&cfg, kind);
            if a.entry(&format!("{base}.u")).is_ok() {
                let boxed = load_packed_linear(&a, &base, n, m)?;
                quantized_layers += 1;
                Ok(boxed)
            } else {
                let t = tensor_of(&a, &format!("{base}.w"))?;
                if t.shape != [n, m] {
                    return Err(invalid(format!(
                        "{base}.w shape {:?} does not match config [{n}, {m}]",
                        t.shape
                    )));
                }
                Ok(Box::new(t))
            }
        };
        blocks.push(DecodeBlock {
            ln1: vec_of(&a, &format!("b{bi}.ln1"), cfg.d_model)?,
            wq: lin(LayerKind::Q)?,
            wk: lin(LayerKind::K)?,
            wv: lin(LayerKind::V)?,
            wo: lin(LayerKind::O)?,
            ln2: vec_of(&a, &format!("b{bi}.ln2"), cfg.d_model)?,
            wg: lin(LayerKind::Gate)?,
            wu: lin(LayerKind::Up)?,
            wd: lin(LayerKind::Down)?,
        });
    }
    let ln_f = vec_of(&a, "ln_f", cfg.d_model)?;
    let head: Option<Box<dyn MatVec>> = if cfg.tied_embeddings {
        None
    } else {
        let h = tensor_of(&a, "head")?;
        if h.shape != [cfg.vocab, cfg.d_model] {
            return Err(invalid(format!("head shape {:?} does not match config", h.shape)));
        }
        Some(Box::new(h))
    };
    Ok(LoadedModel {
        model: DecodeModel { cfg, embed, blocks, ln_f, head },
        file_bytes: a.file_bytes(),
        mapped: a.is_mapped(),
        quantized_layers,
    })
}

/// Out/in dims a decoder linear of `kind` must have under `cfg` — the
/// single source of truth for the layer-shape convention, shared by the
/// loader's validation, the tests, and the benches.
pub fn expected_dims(cfg: &crate::nn::model::ModelConfig, kind: LayerKind) -> (usize, usize) {
    let d = cfg.d_model;
    match kind {
        LayerKind::Q | LayerKind::O => (d, d),
        LayerKind::K | LayerKind::V => (cfg.kv_row(), d),
        LayerKind::Gate | LayerKind::Up => (cfg.d_ff, d),
        LayerKind::Down => (d, cfg.d_ff),
    }
}

/// Assemble one packed linear (`{base}.u/.vt/.s1/.s2`) with zero-copy
/// views, validating every dimension against the config.
fn load_packed_linear(
    a: &Artifact,
    base: &str,
    n: usize,
    m: usize,
) -> std::io::Result<Box<dyn MatVec>> {
    let ue = a.entry(&format!("{base}.u"))?;
    if ue.shape.len() != 2 || ue.shape[0] != n {
        return Err(invalid(format!("{base}.u shape {:?} does not match out dim {n}", ue.shape)));
    }
    let r = ue.shape[1];
    let vte = a.entry(&format!("{base}.vt"))?;
    if vte.shape != [r, m] {
        return Err(invalid(format!(
            "{base}.vt shape {:?} does not match [rank {r}, in dim {m}]",
            vte.shape
        )));
    }
    let u = PackedBits::from_words(n, r, a.bits_view(&format!("{base}.u"))?)
        .map_err(invalid)?;
    let vt = PackedBits::from_words(r, m, a.bits_view(&format!("{base}.vt"))?)
        .map_err(invalid)?;
    let s1 = a.f32_view(&format!("{base}.s1"))?;
    let s2 = a.f32_view(&format!("{base}.s2"))?;
    if s1.len() != n || s2.len() != m {
        return Err(invalid(format!(
            "{base} scale lengths ({}, {}) do not match dims ({n}, {m})",
            s1.len(),
            s2.len()
        )));
    }
    Ok(Box::new(PackedLinear::new(QuantLinear { u, vt, s1, s2 })))
}

fn tensor_of(a: &Artifact, name: &str) -> std::io::Result<Tensor> {
    let e = a.entry(name)?;
    let shape = e.shape.clone();
    Ok(Tensor::new(&shape, a.f32_vec(name)?))
}

fn vec_of(a: &Artifact, name: &str, expect_len: usize) -> std::io::Result<Vec<f32>> {
    let v = a.f32_vec(name)?;
    if v.len() != expect_len {
        return Err(invalid(format!("{name} length {} != expected {expect_len}", v.len())));
    }
    Ok(v)
}

fn invalid<E: ToString>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

/// Deterministic fixture used by the crate's tests and benches: a small
/// quantized model in the zoo shape — every decoder linear of an `l2-xs`
/// teacher replaced by a rank-8 random latent and frozen. Not a trained
/// model; it exists so artifact/store/gateway code paths can exercise
/// real packed layers without running the quantization pipeline.
pub fn quantized_zoo_model(seed: u64) -> QuantModel {
    use crate::nn::family_config;
    use crate::nn::model::ModelParams;
    use crate::quant::scheme::LatentFactors;
    use crate::util::rng::Rng;
    let cfg = family_config("l2", "xs");
    let mut rng = Rng::new(seed);
    let teacher = ModelParams::init(&cfg, &mut rng);
    let mut qm = QuantModel::from_teacher(&teacher);
    for bi in 0..cfg.n_layers {
        for kind in LayerKind::ALL {
            let (n, m) = expected_dims(&cfg, kind);
            let mut lrng = Rng::new(seed ^ ((bi as u64) << 8) ^ kind as u64);
            let lat = LatentFactors {
                u: Tensor::randn(&[n, 8], 1.0, &mut lrng),
                v: Tensor::randn(&[m, 8], 1.0, &mut lrng),
                s1: (0..n).map(|_| lrng.uniform_in(0.5, 1.5)).collect(),
                s2: (0..m).map(|_| lrng.uniform_in(0.5, 1.5)).collect(),
            };
            qm.set_layer(LayerId { block: bi, kind }, lat);
        }
        qm.freeze_block(bi);
    }
    qm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::decode::generate_greedy;
    use crate::nn::family_config;
    use crate::nn::model::ModelParams;
    use crate::quant::scheme::LatentFactors;
    use crate::quant::Engine;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_preserves_forward_bits_across_backings() {
        let qm = quantized_zoo_model(42);
        let path = "/tmp/nanoquant_test_packed_roundtrip.nqck";
        save_packed_model(path, &qm).unwrap();

        let reference = qm.to_decode_model(Engine::Packed);
        let heap = load_packed_model(path, Backing::Heap, true).unwrap();
        let mapped = load_packed_model(path, Backing::Mmap, true).unwrap();
        assert!(!heap.mapped);
        assert_eq!(heap.quantized_layers, 2 * 7);
        assert_eq!(mapped.quantized_layers, 2 * 7);
        assert_eq!(heap.model.cfg, reference.cfg);

        // Single-layer probe: all three engines agree bit for bit.
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(reference.cfg.d_model, 1.0);
        let want = reference.blocks[0].wq.matvec(&x);
        assert_eq!(heap.model.blocks[0].wq.matvec(&x), want);
        assert_eq!(mapped.model.blocks[0].wq.matvec(&x), want);

        // Whole-model acceptance: byte-identical greedy generations.
        let prompt: Vec<u16> = (0..11).map(|i| (i * 17 % 250) as u16).collect();
        let want = generate_greedy(&reference, &prompt, 8, &[]);
        assert_eq!(generate_greedy(&heap.model, &prompt, 8, &[]), want);
        assert_eq!(generate_greedy(&mapped.model, &prompt, 8, &[]), want);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn partially_quantized_models_mix_packed_and_dense() {
        // Quantize only block 0's attention; everything else stays dense.
        let cfg = family_config("l2", "xs");
        let mut rng = Rng::new(5);
        let teacher = ModelParams::init(&cfg, &mut rng);
        let mut qm = QuantModel::from_teacher(&teacher);
        for kind in [LayerKind::Q, LayerKind::O] {
            let (n, m) = expected_dims(&cfg, kind);
            let lat = LatentFactors {
                u: Tensor::randn(&[n, 6], 1.0, &mut rng),
                v: Tensor::randn(&[m, 6], 1.0, &mut rng),
                s1: (0..n).map(|_| rng.uniform_in(0.5, 1.5)).collect(),
                s2: (0..m).map(|_| rng.uniform_in(0.5, 1.5)).collect(),
            };
            qm.set_layer(LayerId { block: 0, kind }, lat);
        }
        qm.freeze_block(0);
        let path = "/tmp/nanoquant_test_packed_partial.nqck";
        save_packed_model(path, &qm).unwrap();
        let loaded = load_packed_model(path, Backing::Mmap, true).unwrap();
        assert_eq!(loaded.quantized_layers, 2);
        let reference = qm.to_decode_model(Engine::Packed);
        let prompt: Vec<u16> = vec![3, 1, 4, 1, 5];
        assert_eq!(
            generate_greedy(&loaded.model, &prompt, 6, &[]),
            generate_greedy(&reference, &prompt, 6, &[])
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_kind_and_dim_mismatches_are_rejected() {
        // An FP checkpoint is a valid NANOQCK2 artifact of the wrong kind.
        let cfg = family_config("l2", "xs");
        let mut rng = Rng::new(9);
        let params = ModelParams::init(&cfg, &mut rng);
        let path = "/tmp/nanoquant_test_packed_wrongkind.nqck";
        crate::nn::checkpoint::save_model(path, &params).unwrap();
        let err = load_packed_model(path, Backing::Heap, true).unwrap_err();
        assert!(err.to_string().contains("kind"), "{err}");
        std::fs::remove_file(path).ok();
    }
}
