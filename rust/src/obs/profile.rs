//! Tick/phase profiler for the serving engine.
//!
//! One scheduler tick (`serve::Engine::step`) is a fixed pipeline of
//! phases; the profiler answers "where does a tick's wall time go at width
//! 8?" without perturbing the thing it measures. The design constraints,
//! in order:
//!
//! 1. **No heap allocation, ever.** Per-tick accumulation is a stack array
//!    of [`NPHASES`] seconds ([`TickProfiler::finish_tick`] recycles it);
//!    the aggregate is a fixed array of [`Histogram`]s. The engine's
//!    steady-state allocation-freeness (pinned by tests since the batched
//!    decode PR) survives with the profiler on *or* off.
//! 2. **No-op when disabled.** [`TickProfiler::begin`] returns `None`
//!    without touching the clock, and every other entry point early-outs
//!    on the flag, so a disabled profiler costs a branch per phase and
//!    cannot move timestamps, outputs, or allocations (byte-identity is
//!    pinned by a test).
//! 3. **Tick granularity, not per-call.** Phases are timed once per tick,
//!    not per matvec: the engine's unit of scheduling is the tick, the
//!    interesting regressions (admission stalls, GEMM-vs-attention balance
//!    at a given width) show up at that grain, and a per-call profiler
//!    would pay a clock read per kernel invocation on a path where a whole
//!    layer can cost less than a syscall.
//!
//! Phase timings measured inside `nn::decode::decode_batch_into` (GEMM vs
//! attention split) arrive via [`TickProfiler::add`] from the scratch
//! arena's accumulators rather than a begin/end pair, keeping `nn` free of
//! any `obs` dependency.

use std::time::Instant;

use super::hist::Histogram;

/// Phases of one engine tick, in execution order. `DrainCommands` is
/// recorded by the bridge thread (command drain happens between ticks);
/// `BatchGemm`/`BatchAttn` are split out of the batched decode call via
/// the scratch arena's accumulators; everything else brackets a block of
/// `serve::Engine::step`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Bridge-side: draining the command channel before the tick.
    DrainCommands = 0,
    /// Cancellations, shed/instant-done drains, queued-deadline expiry.
    Triage,
    /// Class-strict + DRR admission, including prefix-cache probes.
    Admission,
    /// Serial page attach for freshly admitted slots.
    PageAttach,
    /// Parallel chunked prefill across slots.
    Prefill,
    /// Moving slot KV caches into the batch staging area.
    Gather,
    /// Cross-request GEMM work inside `decode_batch_into` (projections,
    /// MLP, vocab head).
    BatchGemm,
    /// Per-slot attention inside `decode_batch_into`.
    BatchAttn,
    /// Moving KV caches back out of the batch staging area.
    Scatter,
    /// Sampling, stop-token checks, streaming, and slot finish.
    Sampling,
    /// End-of-tick page-ledger consistency check + reclaim accounting.
    Reclaim,
}

/// Number of [`Phase`] variants; sizes every per-phase array.
pub const NPHASES: usize = 11;

/// All phases in execution order, index-aligned with their discriminants.
pub const ALL_PHASES: [Phase; NPHASES] = [
    Phase::DrainCommands,
    Phase::Triage,
    Phase::Admission,
    Phase::PageAttach,
    Phase::Prefill,
    Phase::Gather,
    Phase::BatchGemm,
    Phase::BatchAttn,
    Phase::Scatter,
    Phase::Sampling,
    Phase::Reclaim,
];

impl Phase {
    /// Stable snake_case name, used as the `phase` label in Prometheus
    /// exposition and as the Chrome-trace event name.
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::DrainCommands => "drain_commands",
            Phase::Triage => "triage",
            Phase::Admission => "admission",
            Phase::PageAttach => "page_attach",
            Phase::Prefill => "prefill",
            Phase::Gather => "gather",
            Phase::BatchGemm => "batch_gemm",
            Phase::BatchAttn => "batch_attn",
            Phase::Scatter => "scatter",
            Phase::Sampling => "sampling",
            Phase::Reclaim => "reclaim",
        }
    }
}

/// Per-engine tick profiler: a recycled per-tick arena of phase seconds,
/// folded into per-phase log2 histograms at tick end. Owned by the engine
/// (single-threaded custody, like every other engine structure), so no
/// locks anywhere.
#[derive(Clone, Debug)]
pub struct TickProfiler {
    enabled: bool,
    /// Current-tick accumulation, seconds per phase. Recycled (zeroed) by
    /// `finish_tick`, never reallocated.
    cur: [f64; NPHASES],
    /// Aggregate distribution of per-tick phase seconds.
    hist: [Histogram; NPHASES],
    /// Ticks folded into `hist` (idle early-return ticks included).
    ticks: u64,
}

impl TickProfiler {
    pub fn new(enabled: bool) -> TickProfiler {
        TickProfiler {
            enabled,
            cur: [0.0; NPHASES],
            hist: std::array::from_fn(|_| Histogram::seconds()),
            ticks: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Start timing a phase. Returns `None` without reading the clock when
    /// disabled — the caller threads the token to [`TickProfiler::end`],
    /// so a disabled profiler performs zero clock syscalls per tick.
    #[inline]
    pub fn begin(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a phase opened by [`TickProfiler::begin`], accumulating its
    /// elapsed time into the current tick. Multiple begin/end pairs for
    /// the same phase within one tick sum.
    #[inline]
    pub fn end(&mut self, phase: Phase, started: Option<Instant>) {
        if let Some(t) = started {
            self.cur[phase as usize] += t.elapsed().as_secs_f64();
        }
    }

    /// Accumulate externally measured seconds (e.g. the GEMM/attention
    /// split reported by the batch scratch arena) into the current tick.
    #[inline]
    pub fn add(&mut self, phase: Phase, secs: f64) {
        if self.enabled {
            self.cur[phase as usize] += secs;
        }
    }

    /// Fold the current tick's phase times into the aggregate histograms
    /// and recycle the arena. Phases that saw no time this tick are not
    /// recorded (a histogram of "0s admission on idle ticks" would bury
    /// the signal).
    pub fn finish_tick(&mut self) {
        if !self.enabled {
            return;
        }
        for i in 0..NPHASES {
            if self.cur[i] > 0.0 {
                self.hist[i].record(self.cur[i]);
            }
            self.cur[i] = 0.0;
        }
        self.ticks += 1;
    }

    /// Aggregate per-phase histograms, index-aligned with [`ALL_PHASES`].
    pub fn histograms(&self) -> &[Histogram; NPHASES] {
        &self.hist
    }

    /// Ticks folded so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Clear all aggregates (engine `reset`), keeping the enabled flag.
    pub fn reset(&mut self) {
        self.cur = [0.0; NPHASES];
        for h in self.hist.iter_mut() {
            h.reset();
        }
        self.ticks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_never_touches_the_clock_or_histograms() {
        let mut p = TickProfiler::new(false);
        let t = p.begin();
        assert!(t.is_none(), "disabled begin must not read the clock");
        p.end(Phase::Admission, t);
        p.add(Phase::BatchGemm, 1.0);
        p.finish_tick();
        assert_eq!(p.ticks(), 0);
        assert!(p.histograms().iter().all(|h| h.count() == 0));
    }

    #[test]
    fn enabled_profiler_folds_phases_per_tick() {
        let mut p = TickProfiler::new(true);
        let t = p.begin();
        assert!(t.is_some());
        p.end(Phase::Admission, t);
        p.add(Phase::BatchGemm, 0.25);
        p.add(Phase::BatchGemm, 0.25); // same phase sums within a tick
        p.finish_tick();
        assert_eq!(p.ticks(), 1);
        let h = &p.histograms()[Phase::BatchGemm as usize];
        assert_eq!(h.count(), 1, "one tick = one sample per active phase");
        assert!((h.sum() - 0.5).abs() < 1e-12);
        // Inactive phases record nothing.
        assert_eq!(p.histograms()[Phase::Prefill as usize].count(), 0);
        // Arena is recycled.
        p.finish_tick();
        assert_eq!(p.ticks(), 2);
        assert_eq!(p.histograms()[Phase::BatchGemm as usize].count(), 1);
    }

    #[test]
    fn phase_discriminants_align_with_all_phases() {
        for (i, ph) in ALL_PHASES.iter().enumerate() {
            assert_eq!(*ph as usize, i);
        }
    }

    #[test]
    fn reset_clears_aggregates() {
        let mut p = TickProfiler::new(true);
        p.add(Phase::Triage, 0.1);
        p.finish_tick();
        p.reset();
        assert_eq!(p.ticks(), 0);
        assert!(p.histograms().iter().all(|h| h.count() == 0));
    }
}
