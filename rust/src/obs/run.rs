//! Quantization-run observer: structured NDJSON progress events,
//! convergence traces, an EWMA block ETA, and a divergence watchdog for
//! the PTQ pipeline (`quant::pipeline`).
//!
//! PR 9 gave the *serving* stack histograms, a tick profiler and
//! Prometheus; this module gives the *quantization* stack the same
//! treatment. A multi-hour `quantize` run (the paper's 70B-in-13h regime)
//! is only launchable responsibly if it (a) streams machine-readable
//! progress, (b) can estimate completion, and (c) kills itself early when
//! the optimization has diverged instead of burning the remaining hours.
//!
//! Design constraints, in order:
//!
//! 1. **Strictly opt-in.** The observer is threaded through the pipeline
//!    as `Option<&mut RunObserver>`. With `None`, the quantization path
//!    takes **zero clock reads** and allocates nothing it didn't before —
//!    packed bits and scales are byte-identical to the pre-observer code
//!    (pinned by `quant::pipeline::tests::observer_toggle_is_bit_identical`,
//!    mirroring the serving stack's `--no-obs` invariant).
//! 2. **One schema, pinned.** Events are NDJSON — one [`crate::util::json::Json`]
//!    object per line. `Json` objects serialize from a `BTreeMap`, so keys
//!    appear in deterministic alphabetical order; the golden event-schema
//!    test pins the exact key set of every event type. Every event carries
//!    `ev` (type) and `t` (seconds since run start).
//! 3. **Bounded volume.** Per-iteration ADMM curves are decimated to at
//!    most [`MAX_CURVE_POINTS`] points per layer before emission (first
//!    and last iterations always kept), so a 400-iteration × 7-layer ×
//!    80-block run emits kilobytes, not the raw trace.
//!
//! ## Event stream
//!
//! | `ev`             | payload                                                        |
//! |------------------|----------------------------------------------------------------|
//! | `run_started`    | model shape, bpw, rank, calib size, ADMM config, watchdog      |
//! | `phase_started`  | `phase` ∈ calibration / block_recon / global_recon             |
//! | `phase_done`     | `phase`, wall `seconds`                                        |
//! | `block_started`  | `block`, `n_blocks`                                            |
//! | `admm_trace`     | per-layer decimated `iter`/`primal`/`dual`/`rho`/`objective`   |
//! | `mitigate_curve` | per-block decimated `step`/`loss`                              |
//! | `ste_curve`      | per-block decimated `step`/`loss`                              |
//! | `recon_curve`    | global-phase decimated `step`/`loss`                           |
//! | `block_done`     | `err_before`/`err_after`, block `seconds`, `eta_s`             |
//! | `watchdog`       | `stage`, `step`, `reason`, `action` (warn \| abort)            |
//! | `run_done`       | totals: `blocks`, `seconds`, `effective_bpw`/`bytes`           |
//!
//! ## Watchdog policy
//!
//! Loss streams (mitigate / STE / global recon) are checked per step: a
//! non-finite value triggers immediately; otherwise a running best is
//! tracked and [`RunObserver::with_patience`] steps without a relative
//! improvement of `min_rel_improve` triggers a stall. ADMM residual
//! curves are checked for non-finite values only — the primal residual is
//! not monotone under a ramping ρ, so stall detection there would
//! false-positive on healthy runs. `warn` emits one `watchdog` event per
//! stream and continues; `abort` flushes the sink and returns a
//! structured [`RunAborted`] that unwinds out of `quantize_observed`.
//!
//! ## ETA model
//!
//! Sequential block reconstruction dominates the run and per-block cost
//! is near-stationary (same shapes every block), so the ETA is an
//! exponentially-weighted moving average of completed block wall times
//! (`alpha` = [`ETA_ALPHA`]) times the number of remaining blocks —
//! robust to a slow first block (allocator warmup) without the lag of a
//! plain mean.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::time::Instant;

use super::hist::Histogram;
use crate::util::json::Json;

/// Decimation cap for every emitted curve (ADMM iterations, loss curves).
pub const MAX_CURVE_POINTS: usize = 64;

/// EWMA coefficient for the per-block wall-time estimate behind `eta_s`.
pub const ETA_ALPHA: f64 = 0.3;

/// Where NDJSON events go. `Memory` backs the in-process golden tests and
/// the bench's overhead measurement (no filesystem noise in the timing).
pub enum EventSink {
    Stderr,
    File(BufWriter<File>),
    Memory(Vec<String>),
}

impl EventSink {
    /// Open `path` for NDJSON events, creating parent directories (same
    /// convention as [`crate::util::json::write_json`]).
    pub fn file(path: &str) -> std::io::Result<EventSink> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        Ok(EventSink::File(BufWriter::new(File::create(path)?)))
    }

    pub fn memory() -> EventSink {
        EventSink::Memory(Vec::new())
    }

    fn write_line(&mut self, line: &str) {
        match self {
            EventSink::Stderr => eprintln!("{line}"),
            // Event-stream writes are best-effort: a full disk must not
            // kill a quantization run that is otherwise healthy.
            EventSink::File(w) => {
                let _ = writeln!(w, "{line}");
            }
            EventSink::Memory(v) => v.push(line.to_string()),
        }
    }

    fn flush(&mut self) {
        if let EventSink::File(w) = self {
            let _ = w.flush();
        }
    }
}

/// Divergence-watchdog policy (`--watchdog off|warn|abort`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Watchdog {
    /// No stream checks at all (the default).
    Off,
    /// Emit one `watchdog` event per diverging stream, keep running.
    Warn,
    /// Flush the sink and return a structured [`RunAborted`].
    Abort,
}

impl Watchdog {
    pub fn parse(s: &str) -> Result<Watchdog, String> {
        match s {
            "off" => Ok(Watchdog::Off),
            "warn" => Ok(Watchdog::Warn),
            "abort" => Ok(Watchdog::Abort),
            _ => Err(format!("unknown watchdog policy '{s}' (expected one of: off, warn, abort)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Watchdog::Off => "off",
            Watchdog::Warn => "warn",
            Watchdog::Abort => "abort",
        }
    }
}

/// Structured error returned when the `abort` watchdog fires: which stage
/// diverged, where, and why — instead of hours of NaN arithmetic.
#[derive(Clone, Debug)]
pub struct RunAborted {
    /// Diverging stream: `mitigate`, `admm`, `ste`, or `recon`.
    pub stage: String,
    /// Block being reconstructed, if the stage is block-scoped.
    pub block: Option<usize>,
    /// Step (or ADMM iteration) at which the trigger fired.
    pub step: usize,
    pub reason: String,
}

impl std::fmt::Display for RunAborted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.block {
            Some(b) => write!(
                f,
                "watchdog aborted quantization: {} diverged at block {b}, step {}: {}",
                self.stage, self.step, self.reason
            ),
            None => write!(
                f,
                "watchdog aborted quantization: {} diverged at step {}: {}",
                self.stage, self.step, self.reason
            ),
        }
    }
}

impl std::error::Error for RunAborted {}

/// Per-stream divergence state (running best + steps since improvement).
struct StreamState {
    best: f64,
    since_improve: usize,
    warned: bool,
}

/// The quantization-run observer. Construct one and pass
/// `Some(&mut observer)` to `quant::quantize_observed`; pass `None` (or
/// call plain `quantize`) for the telemetry-free path.
pub struct RunObserver {
    sink: Option<EventSink>,
    progress: bool,
    watchdog: Watchdog,
    patience: usize,
    min_rel_improve: f64,
    start: Instant,
    n_blocks: usize,
    blocks_done: usize,
    cur_block: Option<usize>,
    ewma_block_s: Option<f64>,
    block_t0: Option<Instant>,
    phase_t0: Option<(String, Instant)>,
    /// Wall-time histograms, keyed `phase:<name>` / `step:<name>`, in
    /// first-recorded order (moved into `QuantReport::phase_hists`).
    hists: Vec<(String, Histogram)>,
    streams: BTreeMap<String, StreamState>,
}

impl RunObserver {
    /// `sink`: where NDJSON events go (`None` = progress/watchdog only).
    /// `progress`: human TTY progress line on stderr.
    pub fn new(sink: Option<EventSink>, progress: bool, watchdog: Watchdog) -> RunObserver {
        RunObserver {
            sink,
            progress,
            watchdog,
            patience: 64,
            min_rel_improve: 1e-4,
            start: Instant::now(),
            n_blocks: 0,
            blocks_done: 0,
            cur_block: None,
            ewma_block_s: None,
            block_t0: None,
            phase_t0: None,
            hists: Vec::new(),
            streams: BTreeMap::new(),
        }
    }

    /// Override the stall detector: trigger after `patience` consecutive
    /// steps without a relative improvement of at least `min_rel_improve`.
    /// The default (64 steps, 1e-4) is deliberately wider than the
    /// pipeline's default step budgets, so stalls only fire on runs long
    /// enough for the signal to be meaningful.
    pub fn with_patience(mut self, patience: usize, min_rel_improve: f64) -> RunObserver {
        self.patience = patience.max(1);
        self.min_rel_improve = min_rel_improve;
        self
    }

    /// Captured event lines (memory sinks only; empty otherwise).
    pub fn events(&self) -> &[String] {
        match &self.sink {
            Some(EventSink::Memory(v)) => v,
            _ => &[],
        }
    }

    /// Seconds since the observer (hence the run) started.
    fn t(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn emit(&mut self, ev: Json) {
        if let Some(sink) = &mut self.sink {
            sink.write_line(&ev.to_string());
        }
    }

    fn hist_mut(&mut self, name: &str) -> &mut Histogram {
        if let Some(i) = self.hists.iter().position(|(n, _)| n == name) {
            return &mut self.hists[i].1;
        }
        self.hists.push((name.to_string(), Histogram::seconds()));
        &mut self.hists.last_mut().unwrap().1
    }

    /// Move the accumulated wall-time histograms out (into `QuantReport`).
    pub fn take_hists(&mut self) -> Vec<(String, Histogram)> {
        std::mem::take(&mut self.hists)
    }

    // ---- Run / phase / block lifecycle ---------------------------------

    /// Emit `run_started`. `info` is the pipeline's config/model payload;
    /// the observer adds `ev`, `t`, `n_blocks` and its watchdog policy.
    pub fn run_started(&mut self, n_blocks: usize, mut info: Json) {
        self.n_blocks = n_blocks;
        info.insert("ev", "run_started");
        info.insert("t", self.t());
        info.insert("n_blocks", n_blocks);
        info.insert("watchdog", self.watchdog.as_str());
        self.emit(info);
        if self.progress {
            eprintln!("[nanoquant] quantization started: {n_blocks} blocks");
        }
    }

    pub fn phase_started(&mut self, phase: &str) {
        self.phase_t0 = Some((phase.to_string(), Instant::now()));
        let ev = Json::obj().set("ev", "phase_started").set("phase", phase).set("t", self.t());
        self.emit(ev);
    }

    pub fn phase_done(&mut self, phase: &str) {
        let seconds = match self.phase_t0.take() {
            Some((name, t0)) => {
                debug_assert_eq!(name, phase, "phase_done without matching phase_started");
                t0.elapsed().as_secs_f64()
            }
            None => 0.0,
        };
        self.cur_block = None;
        self.hist_mut(&format!("phase:{phase}")).record(seconds);
        let ev = Json::obj()
            .set("ev", "phase_done")
            .set("phase", phase)
            .set("seconds", seconds)
            .set("t", self.t());
        self.emit(ev);
    }

    pub fn block_started(&mut self, block: usize) {
        self.cur_block = Some(block);
        self.block_t0 = Some(Instant::now());
        // Fresh block, fresh loss scales: reset the divergence streams.
        self.streams.clear();
        let ev = Json::obj()
            .set("ev", "block_started")
            .set("block", block)
            .set("n_blocks", self.n_blocks)
            .set("t", self.t());
        self.emit(ev);
    }

    pub fn block_done(&mut self, block: usize, err_before: f64, err_after: f64) {
        let seconds = self.block_t0.take().map(|t0| t0.elapsed().as_secs_f64()).unwrap_or(0.0);
        self.blocks_done += 1;
        let ewma = match self.ewma_block_s {
            None => seconds,
            Some(prev) => ewma_update(prev, seconds),
        };
        self.ewma_block_s = Some(ewma);
        let remaining = self.n_blocks.saturating_sub(self.blocks_done);
        let eta_s = ewma * remaining as f64;
        let ev = Json::obj()
            .set("ev", "block_done")
            .set("block", block)
            .set("blocks_done", self.blocks_done)
            .set("n_blocks", self.n_blocks)
            .set("err_before", err_before)
            .set("err_after", err_after)
            .set("seconds", seconds)
            .set("eta_s", eta_s)
            .set("t", self.t());
        self.emit(ev);
        if self.progress {
            eprint!(
                "\r[nanoquant] block {}/{}  err {:.4}  eta {:.0}s   ",
                self.blocks_done, self.n_blocks, err_after, eta_s
            );
        }
    }

    /// Emit `run_done`, print the closing progress line, flush the sink.
    pub fn run_done(&mut self, effective_bpw: f64, effective_bytes: usize) {
        let seconds = self.t();
        let ev = Json::obj()
            .set("ev", "run_done")
            .set("blocks", self.blocks_done)
            .set("effective_bpw", effective_bpw)
            .set("effective_bytes", effective_bytes)
            .set("seconds", seconds)
            .set("t", seconds);
        self.emit(ev);
        if self.progress {
            eprintln!(
                "\r[nanoquant] done: {} blocks in {seconds:.1}s ({effective_bpw:.3} bpw)      ",
                self.blocks_done
            );
        }
        if let Some(sink) = &mut self.sink {
            sink.flush();
        }
    }

    // ---- Sub-step wall-time histograms ---------------------------------

    /// Start timing a pipeline sub-step. Only ever called when an observer
    /// exists, so the telemetry-off path keeps its zero-clock-read
    /// invariant.
    pub fn step_start(&self) -> Instant {
        Instant::now()
    }

    /// Record `step:<name>` wall time since `t0`. No event — per-step
    /// timing is histogram-only; the NDJSON stream stays block-grained.
    pub fn step_done(&mut self, name: &str, t0: Instant) {
        let secs = t0.elapsed().as_secs_f64();
        self.hist_mut(&format!("step:{name}")).record(secs);
    }

    // ---- Convergence curves + watchdog ---------------------------------

    /// Emit a decimated `<stage>_curve` event (no-op for empty curves).
    pub fn curve(&mut self, stage: &str, losses: &[f64]) {
        if losses.is_empty() {
            return;
        }
        let idx = decimate_indices(losses.len(), MAX_CURVE_POINTS);
        let steps: Vec<Json> = idx.iter().map(|&i| Json::Num(i as f64)).collect();
        let vals: Vec<Json> = idx.iter().map(|&i| Json::Num(losses[i])).collect();
        let mut ev = Json::obj()
            .set("ev", format!("{stage}_curve"))
            .set("step", Json::Arr(steps))
            .set("loss", Json::Arr(vals))
            .set("t", self.t());
        if let Some(b) = self.cur_block {
            ev.insert("block", b);
        }
        self.emit(ev);
    }

    /// Feed one per-layer ADMM trace: emit the decimated `admm_trace`
    /// event and run the non-finite check over the residual/objective
    /// curves. `objective` may be empty (the expensive recon-err trace is
    /// only recorded for block 0 by default).
    pub fn admm_layer(
        &mut self,
        layer: &str,
        iters_run: usize,
        primal: &[f64],
        dual: &[f64],
        rho: &[f64],
        objective: &[f64],
    ) -> Result<(), RunAborted> {
        let idx = decimate_indices(primal.len(), MAX_CURVE_POINTS);
        let pick = |xs: &[f64]| -> Json {
            Json::Arr(idx.iter().filter_map(|&i| xs.get(i).map(|&v| Json::Num(v))).collect())
        };
        let ev = Json::obj()
            .set("ev", "admm_trace")
            .set("layer", layer)
            .set("block", self.cur_block.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null))
            .set("iters_run", iters_run)
            .set("points", primal.len())
            .set("iter", Json::Arr(idx.iter().map(|&i| Json::Num(i as f64)).collect()))
            .set("primal", pick(primal))
            .set("dual", pick(dual))
            .set("rho", pick(rho))
            .set("objective", pick(objective))
            .set("t", self.t());
        self.emit(ev);
        if self.watchdog == Watchdog::Off {
            return Ok(());
        }
        for (k, &v) in primal.iter().enumerate() {
            if !v.is_finite() {
                let reason = format!("non-finite primal residual ({v}) in layer {layer}");
                return self.trigger("admm", k, reason);
            }
        }
        for (k, &v) in objective.iter().enumerate() {
            if !v.is_finite() {
                let reason = format!("non-finite objective ({v}) in layer {layer}");
                return self.trigger("admm", k, reason);
            }
        }
        Ok(())
    }

    /// Feed one loss-stream step into the divergence watchdog. Returns
    /// `Err(RunAborted)` only under the `abort` policy.
    pub fn scalar_step(
        &mut self,
        stage: &'static str,
        step: usize,
        value: f64,
    ) -> Result<(), RunAborted> {
        if self.watchdog == Watchdog::Off {
            return Ok(());
        }
        if !value.is_finite() {
            return self.trigger(stage, step, format!("non-finite loss ({value})"));
        }
        let (patience, min_rel) = (self.patience, self.min_rel_improve);
        let st = self.streams.entry(stage.to_string()).or_insert(StreamState {
            best: value,
            since_improve: 0,
            warned: false,
        });
        let improved = value < st.best - min_rel * st.best.abs().max(1e-12);
        if improved {
            st.best = value;
            st.since_improve = 0;
            return Ok(());
        }
        st.since_improve += 1;
        if st.since_improve >= patience {
            let best = st.best;
            st.since_improve = 0; // re-arm (warn mode keeps running)
            let reason =
                format!("no improvement in {patience} steps (best {best:.6e}, last {value:.6e})");
            return self.trigger(stage, step, reason);
        }
        Ok(())
    }

    /// Emit the `watchdog` event and apply the policy.
    fn trigger(&mut self, stage: &str, step: usize, reason: String) -> Result<(), RunAborted> {
        // Warn-once per stream per block: a stalled stream would otherwise
        // re-trigger every `patience` steps.
        if self.watchdog == Watchdog::Warn {
            if let Some(st) = self.streams.get_mut(stage) {
                if st.warned {
                    return Ok(());
                }
                st.warned = true;
            }
        }
        let block = self.cur_block;
        let ev = Json::obj()
            .set("ev", "watchdog")
            .set("stage", stage)
            .set("block", block.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null))
            .set("step", step)
            .set("reason", reason.as_str())
            .set("action", self.watchdog.as_str())
            .set("t", self.t());
        self.emit(ev);
        if self.progress {
            eprintln!("\n[nanoquant] watchdog ({}): {stage}: {reason}", self.watchdog.as_str());
        }
        match self.watchdog {
            Watchdog::Abort => {
                if let Some(sink) = &mut self.sink {
                    sink.flush();
                }
                Err(RunAborted { stage: stage.to_string(), block, step, reason })
            }
            _ => Ok(()),
        }
    }
}

/// One EWMA step for the per-block wall-time estimate.
pub fn ewma_update(prev: f64, x: f64) -> f64 {
    ETA_ALPHA * x + (1.0 - ETA_ALPHA) * prev
}

/// Stride-sampled indices into a curve of length `len`, at most `cap`
/// points, always including the first and last index.
pub fn decimate_indices(len: usize, cap: usize) -> Vec<usize> {
    debug_assert!(cap >= 2);
    if len <= cap {
        return (0..len).collect();
    }
    let stride = len.div_ceil(cap);
    let mut idx: Vec<usize> = (0..len).step_by(stride).collect();
    match idx.last() {
        Some(&last) if last != len - 1 => {
            if idx.len() >= cap {
                *idx.last_mut().unwrap() = len - 1;
            } else {
                idx.push(len - 1);
            }
        }
        _ => {}
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_events(obs: &RunObserver) -> Vec<Json> {
        obs.events().iter().map(|l| Json::parse(l).expect("event line parses")).collect()
    }

    #[test]
    fn watchdog_parse_lists_accepted_values() {
        assert_eq!(Watchdog::parse("off").unwrap(), Watchdog::Off);
        assert_eq!(Watchdog::parse("warn").unwrap(), Watchdog::Warn);
        assert_eq!(Watchdog::parse("abort").unwrap(), Watchdog::Abort);
        let err = Watchdog::parse("panic").unwrap_err();
        assert!(err.contains("off") && err.contains("warn") && err.contains("abort"), "{err}");
    }

    #[test]
    fn decimation_caps_and_keeps_endpoints() {
        for len in [0usize, 1, 2, 63, 64, 65, 100, 129, 400, 4001] {
            let idx = decimate_indices(len, MAX_CURVE_POINTS);
            assert!(idx.len() <= MAX_CURVE_POINTS, "len={len} gave {} points", idx.len());
            if len > 0 {
                assert_eq!(idx[0], 0, "len={len}");
                assert_eq!(*idx.last().unwrap(), len - 1, "len={len}");
            }
            if len <= MAX_CURVE_POINTS {
                assert_eq!(idx.len(), len);
            }
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "strictly increasing, len={len}");
        }
    }

    #[test]
    fn nonfinite_loss_aborts_immediately() {
        let mut obs = RunObserver::new(Some(EventSink::memory()), false, Watchdog::Abort);
        obs.block_started(3);
        obs.scalar_step("ste", 0, 0.5).unwrap();
        let err = obs.scalar_step("ste", 1, f64::NAN).unwrap_err();
        assert_eq!(err.stage, "ste");
        assert_eq!(err.block, Some(3));
        assert_eq!(err.step, 1);
        let evs = parse_events(&obs);
        let wd = evs.iter().find(|e| e.get("ev").unwrap().as_str() == Some("watchdog")).unwrap();
        assert_eq!(wd.get("action").unwrap().as_str(), Some("abort"));
        assert_eq!(wd.get("block").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn stall_detection_honors_patience_and_warns_once() {
        // Warn mode: a flat stream emits exactly one watchdog event.
        let mut obs = RunObserver::new(Some(EventSink::memory()), false, Watchdog::Warn)
            .with_patience(3, 1e-3);
        obs.block_started(0);
        for step in 0..20 {
            obs.scalar_step("mitigate", step, 1.0).unwrap();
        }
        let evs = parse_events(&obs);
        let warns =
            evs.iter().filter(|e| e.get("ev").unwrap().as_str() == Some("watchdog")).count();
        assert_eq!(warns, 1, "warn-once per stream");

        // Abort mode: same stream errors after exactly `patience` flat steps.
        let mut obs = RunObserver::new(None, false, Watchdog::Abort).with_patience(3, 1e-3);
        obs.scalar_step("recon", 0, 1.0).unwrap();
        obs.scalar_step("recon", 1, 1.0).unwrap();
        let err = obs.scalar_step("recon", 2, 1.0).unwrap_err();
        assert!(err.reason.contains("no improvement"), "{}", err.reason);
        assert_eq!(err.block, None);

        // A decreasing stream never triggers.
        let mut obs = RunObserver::new(None, false, Watchdog::Abort).with_patience(3, 1e-3);
        for step in 0..50 {
            obs.scalar_step("ste", step, 1.0 / (1.0 + step as f64)).unwrap();
        }
    }

    #[test]
    fn watchdog_off_ignores_everything() {
        let mut obs = RunObserver::new(Some(EventSink::memory()), false, Watchdog::Off);
        obs.scalar_step("ste", 0, f64::NAN).unwrap();
        obs.scalar_step("ste", 1, f64::INFINITY).unwrap();
        assert!(parse_events(&obs)
            .iter()
            .all(|e| e.get("ev").unwrap().as_str() != Some("watchdog")));
    }

    #[test]
    fn block_streams_reset_between_blocks() {
        // 2 flat steps per block never reach patience=3 because
        // block_started clears the stream state.
        let mut obs = RunObserver::new(None, false, Watchdog::Abort).with_patience(3, 1e-3);
        for b in 0..5 {
            obs.block_started(b);
            obs.scalar_step("mitigate", 0, 1.0).unwrap();
            obs.scalar_step("mitigate", 1, 1.0).unwrap();
            obs.block_done(b, 1.0, 0.5);
        }
    }

    #[test]
    fn lifecycle_events_parse_and_carry_schema() {
        let mut obs = RunObserver::new(Some(EventSink::memory()), false, Watchdog::Warn);
        obs.run_started(2, Json::obj().set("model", "l2-xs").set("bpw", 1.0));
        obs.phase_started("block_recon");
        obs.block_started(0);
        obs.curve("ste", &[1.0, 0.5, 0.25]);
        obs.admm_layer("blk0.q", 3, &[0.5, 0.4, 0.3], &[0.1, 0.1, 0.1], &[1.0, 2.0, 3.0], &[])
            .unwrap();
        obs.block_done(0, 0.4, 0.2);
        obs.phase_done("block_recon");
        obs.run_done(1.0, 1234);
        let evs = parse_events(&obs);
        assert_eq!(evs[0].get("ev").unwrap().as_str(), Some("run_started"));
        assert_eq!(evs[0].get("watchdog").unwrap().as_str(), Some("warn"));
        assert_eq!(evs[0].get("n_blocks").unwrap().as_usize(), Some(2));
        let curve = &evs[3];
        assert_eq!(curve.get("ev").unwrap().as_str(), Some("ste_curve"));
        assert_eq!(curve.get("block").unwrap().as_usize(), Some(0));
        assert_eq!(curve.get("loss").unwrap().as_arr().unwrap().len(), 3);
        let admm = &evs[4];
        assert_eq!(admm.get("ev").unwrap().as_str(), Some("admm_trace"));
        assert_eq!(admm.get("points").unwrap().as_usize(), Some(3));
        assert_eq!(admm.get("objective").unwrap().as_arr().unwrap().len(), 0);
        let done = evs.last().unwrap();
        assert_eq!(done.get("ev").unwrap().as_str(), Some("run_done"));
        assert_eq!(done.get("blocks").unwrap().as_usize(), Some(1));
        assert_eq!(done.get("effective_bytes").unwrap().as_usize(), Some(1234));
        // One hist per closed phase, with count conservation.
        let hists = obs.take_hists();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].0, "phase:block_recon");
        assert_eq!(hists[0].1.count(), 1);
    }

    #[test]
    fn ewma_blends_toward_new_samples() {
        let e1 = ewma_update(10.0, 20.0);
        assert!(e1 > 10.0 && e1 < 20.0);
        assert!((ewma_update(5.0, 5.0) - 5.0).abs() < 1e-12);
        // Repeated samples converge to the sample value.
        let mut e = 100.0;
        for _ in 0..60 {
            e = ewma_update(e, 1.0);
        }
        assert!((e - 1.0).abs() < 1e-6);
    }
}
