//! Fixed-shape log2 histogram: the one histogram type every latency and
//! size distribution in the repo records into.
//!
//! Shape is compile-time fixed ([`NBUCKETS`] buckets, geometric base-2
//! edges scaled by a per-histogram `unit`), so recording is a couple of
//! integer ops on a stack array — no heap allocation ever, which is what
//! lets the serving engine record queue waits, TTFTs, inter-token gaps and
//! tick-phase times on the decode hot path without breaking its
//! steady-state allocation-freeness. Two histograms with the same unit are
//! mergeable bucket-wise, so per-shard or per-thread instances can be
//! summed into a fleet view without losing anything but intra-bucket
//! resolution.
//!
//! Bucket layout, for unit `u`:
//!
//! ```text
//! bucket 0:            value < u           (upper edge u)
//! bucket i (1..=26):   u*2^(i-1) <= v < u*2^i   (upper edge u*2^i)
//! bucket 27:           overflow            (upper edge +Inf)
//! ```
//!
//! With the [`Histogram::seconds`] unit of 1µs the finite range tops out at
//! `1µs * 2^26 ≈ 67s`; with the [`Histogram::counts`] unit of 1 it tops
//! out at `2^26 ≈ 6.7e7` — both comfortably beyond anything the serving
//! stack measures.

/// Number of buckets, including the catch-all underflow bucket 0 and the
/// overflow bucket `NBUCKETS - 1` (upper edge `+Inf`).
pub const NBUCKETS: usize = 28;

/// A mergeable fixed-log2-bucket histogram. See the module docs for the
/// bucket layout.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Scale of bucket 0's upper edge; all other edges are `unit * 2^i`.
    unit: f64,
    counts: [u64; NBUCKETS],
    count: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new(1.0)
    }
}

impl Histogram {
    /// A histogram whose bucket 0 upper edge is `unit` (must be finite and
    /// positive).
    pub fn new(unit: f64) -> Histogram {
        debug_assert!(unit.is_finite() && unit > 0.0, "histogram unit must be positive");
        Histogram { unit, counts: [0; NBUCKETS], count: 0, sum: 0.0 }
    }

    /// The standard unit for durations in seconds: bucket 0 is `< 1µs`,
    /// finite edges run up to ~67s.
    pub fn seconds() -> Histogram {
        Histogram::new(1e-6)
    }

    /// The standard unit for dimensionless counts (tokens, batch widths):
    /// bucket 0 is `< 1`, finite edges run up to ~6.7e7.
    pub fn counts() -> Histogram {
        Histogram::new(1.0)
    }

    /// Bucket index for a value: `floor(log2(v / unit)) + 1`, clamped into
    /// range, via integer bit tricks (no `log2` call, no branch misses on
    /// the hot path).
    fn bucket_of(&self, v: f64) -> usize {
        if !(v >= self.unit) {
            // Also catches NaN and negatives: they land in bucket 0, and
            // `record` clamps their sum contribution to 0.
            return 0;
        }
        let r = (v / self.unit) as u64; // >= 1 here
        let idx = 64 - r.leading_zeros() as usize; // floor(log2(r)) + 1
        idx.min(NBUCKETS - 1)
    }

    /// Record one observation. Negative or NaN values count as zeros (they
    /// land in bucket 0 and contribute 0 to the sum) — consistent with the
    /// zero-elapsed guards in `ServeMetrics::snapshot`.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[self.bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Add every bucket of `other` into `self`. Both histograms must share
    /// a unit (same edges), or the merge would be meaningless.
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(
            self.unit.to_bits(),
            other.unit.to_bits(),
            "merging histograms with different units"
        );
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded values (exact, not bucket-approximated).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of recorded values, or 0.0 when empty (zero-count guard
    /// consistent with `ServeMetrics::snapshot`).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// The per-histogram scale (bucket 0's upper edge).
    pub fn unit(&self) -> f64 {
        self.unit
    }

    /// Raw bucket counts, index-aligned with [`Histogram::upper_edge`].
    pub fn buckets(&self) -> &[u64; NBUCKETS] {
        &self.counts
    }

    /// Upper edge of bucket `i`: `unit * 2^i` for finite buckets,
    /// `+Inf` for the overflow bucket.
    pub fn upper_edge(&self, i: usize) -> f64 {
        debug_assert!(i < NBUCKETS);
        if i == NBUCKETS - 1 {
            f64::INFINITY
        } else {
            self.unit * (1u64 << i) as f64
        }
    }

    /// Observations whose *bucket* lies entirely at or below `edge` — the
    /// projection primitive for rendering onto coarser, externally-defined
    /// bucket bounds (e.g. the legacy queue-wait JSON buckets). Because a
    /// bucket is only counted once its whole range fits under `edge`, the
    /// projection is conservative: samples near a coarse edge may be
    /// reported one coarse bucket later, never earlier, and the total is
    /// always preserved.
    pub fn count_le(&self, edge: f64) -> u64 {
        let mut acc = 0;
        for i in 0..NBUCKETS {
            if self.upper_edge(i) <= edge {
                acc += self.counts[i];
            }
        }
        acc
    }

    /// Bucket-resolution quantile: the upper edge of the bucket containing
    /// the `p`-th ordered observation (`0.0 <= p <= 1.0`). Returns 0.0 for
    /// an empty histogram, and the largest finite edge if the quantile
    /// lands in the overflow bucket. An upper edge is the honest answer a
    /// log-bucketed sketch can give: the true value is at most one bucket
    /// width (2x) below it.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 1.0);
        // ceil(p * count), clamped to [1, count]: the rank of the target
        // observation in ascending order.
        let target = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for i in 0..NBUCKETS {
            acc += self.counts[i];
            if acc >= target {
                return if i == NBUCKETS - 1 {
                    self.upper_edge(NBUCKETS - 2)
                } else {
                    self.upper_edge(i)
                };
            }
        }
        self.upper_edge(NBUCKETS - 2)
    }

    /// Reset to empty, keeping the unit.
    pub fn reset(&mut self) {
        self.counts = [0; NBUCKETS];
        self.count = 0;
        self.sum = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_partition_the_line() {
        let h = Histogram::seconds();
        // Exactly-on-edge values belong to the *next* bucket (half-open
        // ranges [lo, hi)).
        assert_eq!(h.bucket_of(0.0), 0);
        assert_eq!(h.bucket_of(0.5e-6), 0);
        assert_eq!(h.bucket_of(1e-6), 1);
        assert_eq!(h.bucket_of(1.5e-6), 1);
        assert_eq!(h.bucket_of(2e-6), 2);
        assert_eq!(h.bucket_of(3.9e-6), 2);
        assert_eq!(h.bucket_of(4e-6), 3);
        assert_eq!(h.bucket_of(f64::MAX), NBUCKETS - 1);
        // Edge values: a value in bucket i is strictly below upper_edge(i)
        // and at least upper_edge(i-1).
        for i in 1..NBUCKETS - 1 {
            let lo = h.upper_edge(i - 1);
            assert_eq!(h.bucket_of(lo), i, "lower edge of bucket {i}");
            assert_eq!(h.bucket_of(lo * 1.5), i, "interior of bucket {i}");
        }
    }

    #[test]
    fn record_merge_and_count_conservation() {
        let mut a = Histogram::seconds();
        let mut b = Histogram::seconds();
        for i in 0..100 {
            a.record(i as f64 * 1e-4);
        }
        for i in 0..50 {
            b.record(i as f64 * 1e-2);
        }
        let (ca, cb, sa, sb) = (a.count(), b.count(), a.sum(), b.sum());
        a.merge(&b);
        assert_eq!(a.count(), ca + cb);
        assert!((a.sum() - (sa + sb)).abs() < 1e-12);
        assert_eq!(a.buckets().iter().sum::<u64>(), a.count());
    }

    #[test]
    fn degenerate_values_count_as_zeros() {
        let mut h = Histogram::seconds();
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 3);
        assert_eq!(h.buckets()[0], 3);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn quantile_walks_buckets() {
        let mut h = Histogram::counts();
        assert_eq!(h.quantile(0.5), 0.0); // empty
        h.record(1.0); // bucket 1 (upper edge 2)
        assert_eq!(h.quantile(0.0), 2.0);
        assert_eq!(h.quantile(1.0), 2.0);
        for _ in 0..99 {
            h.record(1.0);
        }
        h.record(1000.0); // bucket 10 (512..1024), upper edge 1024
        // 100 of 101 samples are tiny: p50 stays in the small bucket, p997+
        // reaches the outlier's bucket edge.
        assert_eq!(h.quantile(0.5), 2.0);
        assert_eq!(h.quantile(0.9999), 1024.0);
        // Overflow-bucket quantiles cap at the largest finite edge.
        let mut o = Histogram::counts();
        o.record(1e30);
        assert_eq!(o.quantile(0.5), o.upper_edge(NBUCKETS - 2));
    }

    #[test]
    fn count_le_projection_is_conservative_and_total_preserving() {
        let mut h = Histogram::seconds();
        let samples = [0.0004, 0.0009, 0.002, 0.05, 0.7, 3.0, 42.0, 120.0];
        for s in samples {
            h.record(s);
        }
        // Coarse legacy bounds; the projection never loses a sample.
        let bounds = [0.001, 0.01, 0.1, 1.0, 10.0];
        let mut cum_prev = 0;
        let mut total = 0;
        for b in bounds {
            let cum = h.count_le(b);
            assert!(cum >= cum_prev, "cumulative counts are monotone");
            total += cum - cum_prev;
            cum_prev = cum;
        }
        total += h.count() - cum_prev; // overflow bucket
        assert_eq!(total, h.count());
        // Conservative: count_le never exceeds the true number of samples
        // <= the bound.
        for b in bounds {
            let truth = samples.iter().filter(|s| **s <= b).count() as u64;
            assert!(h.count_le(b) <= truth, "projection overcounted at {b}");
        }
    }

    #[test]
    fn reset_clears_but_keeps_unit() {
        let mut h = Histogram::seconds();
        h.record(0.5);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.unit(), 1e-6);
    }
}
