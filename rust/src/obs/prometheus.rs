//! Prometheus text exposition (format version 0.0.4), dependency-free.
//!
//! A [`Registry`] is not a long-lived stateful object: the gateway builds
//! one per scrape from the metrics snapshots it already has (engine
//! `ServeMetrics`, KV-pool gauges, prefix-cache stats, threadpool sizes),
//! renders it, and drops it. That keeps the exposition layer out of every
//! hot path — the engine records into its own allocation-free structures;
//! only the scrape pays for strings.
//!
//! Guarantees the renderer enforces:
//! - `# HELP` / `# TYPE` emitted exactly once per metric family, before
//!   its samples, however many label sets report into it.
//! - Metric and label names are linted against the Prometheus grammar
//!   (`[a-zA-Z_:][a-zA-Z0-9_:]*`, labels without the colon); a bad name is
//!   a programming error and panics in debug builds, and the offending
//!   sample is dropped in release builds rather than corrupting the scrape.
//! - Label values are escaped per the spec (`\\`, `\"`, `\n`).
//! - Histograms render cumulative `_bucket{le="..."}` series ending in
//!   `le="+Inf"`, plus `_sum` and `_count`, with `_count` equal to the
//!   `+Inf` bucket.

use super::hist::{Histogram, NBUCKETS};

/// Metric family kinds (the subset the serving stack uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// One labelled sample: a scalar for counters/gauges, a full histogram for
/// histogram families.
enum Sample {
    Scalar { labels: Vec<(String, String)>, value: f64 },
    Hist { labels: Vec<(String, String)>, hist: Histogram },
}

struct Family {
    name: String,
    help: String,
    kind: Kind,
    samples: Vec<Sample>,
}

/// A per-scrape collection of metric families, rendered to exposition
/// text. Families keep registration order; samples keep insertion order
/// within a family.
#[derive(Default)]
pub struct Registry {
    families: Vec<Family>,
}

/// `true` iff `s` is a valid Prometheus metric name:
/// `[a-zA-Z_:][a-zA-Z0-9_:]*`.
pub fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// `true` iff `s` is a valid label name: `[a-zA-Z_][a-zA-Z0-9_]*` (no
/// colons, and the `__` prefix is reserved by Prometheus itself).
pub fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_') && !s.starts_with("__")
}

/// Escape a label value per the exposition format: backslash, double
/// quote, and newline.
pub fn escape_label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape a HELP text: backslash and newline (quotes are fine there).
fn escape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Format a sample value: integral f64s print without a decimal point
/// (Rust's `{}` already does this), infinities as `+Inf`/`-Inf`.
fn fmt_value(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Lint names; `true` when the sample may be recorded. Panics in debug
    /// builds — a bad metric name is a bug in the exporter, not data.
    fn lint(name: &str, labels: &[(&str, &str)]) -> bool {
        let ok =
            valid_metric_name(name) && labels.iter().all(|(k, _)| valid_label_name(k));
        debug_assert!(ok, "invalid metric or label name: {name} {labels:?}");
        ok
    }

    fn family(&mut self, name: &str, help: &str, kind: Kind) -> &mut Family {
        if let Some(i) = self.families.iter().position(|f| f.name == name) {
            let f = &self.families[i];
            debug_assert_eq!(f.kind, kind, "family {name} registered with two kinds");
            return &mut self.families[i];
        }
        self.families.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            samples: Vec::new(),
        });
        self.families.last_mut().expect("just pushed")
    }

    fn owned(labels: &[(&str, &str)]) -> Vec<(String, String)> {
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    /// Add a counter sample (monotonically nondecreasing total).
    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        if !Self::lint(name, labels) {
            return;
        }
        let labels = Self::owned(labels);
        self.family(name, help, Kind::Counter).samples.push(Sample::Scalar { labels, value });
    }

    /// Add a gauge sample (instantaneous value).
    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        if !Self::lint(name, labels) {
            return;
        }
        let labels = Self::owned(labels);
        self.family(name, help, Kind::Gauge).samples.push(Sample::Scalar { labels, value });
    }

    /// Add a histogram sample (one full [`Histogram`] per label set).
    pub fn histogram(&mut self, name: &str, help: &str, labels: &[(&str, &str)], hist: &Histogram) {
        if !Self::lint(name, labels) {
            return;
        }
        let labels = Self::owned(labels);
        self.family(name, help, Kind::Histogram)
            .samples
            .push(Sample::Hist { labels, hist: hist.clone() });
    }

    fn write_labels(out: &mut String, labels: &[(String, String)], extra: Option<(&str, &str)>) {
        if labels.is_empty() && extra.is_none() {
            return;
        }
        out.push('{');
        let mut first = true;
        for (k, v) in labels {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label_value(v));
            out.push('"');
        }
        if let Some((k, v)) = extra {
            if !first {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label_value(v));
            out.push('"');
        }
        out.push('}');
    }

    /// Render the whole registry as exposition text. Serve it with content
    /// type `text/plain; version=0.0.4`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.families {
            out.push_str("# HELP ");
            out.push_str(&f.name);
            out.push(' ');
            out.push_str(&escape_help(&f.help));
            out.push('\n');
            out.push_str("# TYPE ");
            out.push_str(&f.name);
            out.push(' ');
            out.push_str(f.kind.as_str());
            out.push('\n');
            for s in &f.samples {
                match s {
                    Sample::Scalar { labels, value } => {
                        out.push_str(&f.name);
                        Self::write_labels(&mut out, labels, None);
                        out.push(' ');
                        out.push_str(&fmt_value(*value));
                        out.push('\n');
                    }
                    Sample::Hist { labels, hist } => {
                        let mut cum = 0u64;
                        for i in 0..NBUCKETS {
                            cum += hist.buckets()[i];
                            let edge = fmt_value(hist.upper_edge(i));
                            out.push_str(&f.name);
                            out.push_str("_bucket");
                            Self::write_labels(&mut out, labels, Some(("le", &edge)));
                            out.push(' ');
                            out.push_str(&fmt_value(cum as f64));
                            out.push('\n');
                        }
                        out.push_str(&f.name);
                        out.push_str("_sum");
                        Self::write_labels(&mut out, labels, None);
                        out.push(' ');
                        out.push_str(&fmt_value(hist.sum()));
                        out.push('\n');
                        out.push_str(&f.name);
                        out.push_str("_count");
                        Self::write_labels(&mut out, labels, None);
                        out.push(' ');
                        out.push_str(&fmt_value(hist.count() as f64));
                        out.push('\n');
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prometheus_exposition_golden() {
        // Golden-text test for the renderer: a counter family with two
        // label sets, a gauge, and a histogram, exercising label escaping
        // and the _bucket/_sum/_count invariants.
        let mut reg = Registry::new();
        reg.counter(
            "nq_requests_total",
            "Requests by class.",
            &[("class", "interactive")],
            3.0,
        );
        reg.counter("nq_requests_total", "Requests by class.", &[("class", "batch")], 1.0);
        reg.gauge("nq_free_pages", "Free KV pages.", &[], 17.0);
        let mut h = Histogram::counts();
        h.record(1.0); // bucket 1, upper edge 2
        h.record(3.0); // bucket 2, upper edge 4
        reg.histogram(
            "nq_width",
            "Decode batch width.",
            &[("model", "tiny\"v\\1\n")],
            &h,
        );
        let text = reg.render();

        // HELP/TYPE exactly once per family, before its samples.
        assert_eq!(text.matches("# HELP nq_requests_total").count(), 1);
        assert_eq!(text.matches("# TYPE nq_requests_total counter").count(), 1);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "# HELP nq_requests_total Requests by class.");
        assert_eq!(lines[1], "# TYPE nq_requests_total counter");
        assert_eq!(lines[2], "nq_requests_total{class=\"interactive\"} 3");
        assert_eq!(lines[3], "nq_requests_total{class=\"batch\"} 1");
        assert_eq!(lines[4], "# HELP nq_free_pages Free KV pages.");
        assert_eq!(lines[5], "# TYPE nq_free_pages gauge");
        assert_eq!(lines[6], "nq_free_pages 17");

        // Label-value escaping: backslash, quote, newline.
        assert!(
            text.contains("model=\"tiny\\\"v\\\\1\\n\""),
            "escaped label value missing: {text}"
        );

        // Histogram invariants: cumulative buckets ending in +Inf == _count,
        // plus _sum.
        assert!(text.contains("# TYPE nq_width histogram"));
        assert!(text.contains("le=\"1\"} 0"));
        assert!(text.contains("le=\"2\"} 1"));
        assert!(text.contains("le=\"4\"} 2"));
        assert!(text.contains("le=\"+Inf\"} 2"));
        let sum_line = lines.iter().find(|l| l.starts_with("nq_width_sum")).unwrap();
        assert!(sum_line.ends_with(" 4"), "sum of 1+3: {sum_line}");
        let count_line = lines.iter().find(|l| l.starts_with("nq_width_count")).unwrap();
        assert!(count_line.ends_with(" 2"), "{count_line}");

        // Cumulative bucket counts are nondecreasing in le order.
        let mut prev = 0u64;
        for l in lines.iter().filter(|l| l.starts_with("nq_width_bucket")) {
            let v: u64 = l.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "buckets must be cumulative: {l}");
            prev = v;
        }
    }

    #[test]
    fn metric_name_lint() {
        assert!(valid_metric_name("nq_tokens_total"));
        assert!(valid_metric_name("a:b_c1"));
        assert!(valid_metric_name("_x"));
        assert!(!valid_metric_name(""));
        assert!(!valid_metric_name("1abc"));
        assert!(!valid_metric_name("has space"));
        assert!(!valid_metric_name("has-dash"));
        assert!(valid_label_name("class"));
        assert!(!valid_label_name("le:gal"));
        assert!(!valid_label_name("__reserved"));
        assert!(!valid_label_name("9lives"));
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn bad_names_are_dropped_in_release() {
        let mut reg = Registry::new();
        reg.counter("bad-name", "x", &[], 1.0);
        assert_eq!(reg.render(), "");
    }

    #[test]
    fn escaping_rules() {
        assert_eq!(escape_label_value("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
        assert_eq!(escape_label_value("plain"), "plain");
    }

    #[test]
    fn infinity_formats_as_prometheus_expects() {
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(2.0), "2");
        assert_eq!(fmt_value(0.25), "0.25");
    }
}
