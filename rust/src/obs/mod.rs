//! Observability: tick/phase profiling, per-request trace spans, and
//! Prometheus text exposition — dependency-free, allocation-free on the
//! hot path.
//!
//! The layer is three pieces with one shared primitive:
//!
//! - [`hist::Histogram`] — a fixed-shape log2-bucketed histogram (stack
//!   arrays, mergeable, no heap). Every distribution in the repo (queue
//!   wait, TTFT, inter-token gap, tick phase times, prefix-cache hit
//!   length, decode batch width, the traffic generator's TTFT sketch)
//!   records into this one type.
//! - [`profile::TickProfiler`] — per-phase wall time for each engine tick,
//!   accumulated in a recycled arena and folded into per-phase histograms.
//!   Compiled to no-ops when disabled: `begin()` returns `None` without a
//!   clock read, so byte-identity and steady-state allocation-freeness of
//!   the decode path are preserved either way (both pinned by tests).
//! - [`trace::TraceRing`] — a bounded single-owner ring of fixed-size
//!   lifecycle events per request; doubles as the flight recorder (the
//!   last N events survive for post-mortem dumps in Chrome-trace format).
//!
//! [`prometheus::Registry`] is the render-side: the gateway builds one per
//! scrape from the snapshots it already collects and serves
//! `GET /v1/metrics?format=prometheus`, leaving the JSON shape untouched.
//!
//! [`run::RunObserver`] is the quantization-side counterpart: an NDJSON
//! event stream, per-phase wall-time histograms (the same [`Histogram`]),
//! an EWMA block ETA, and a divergence watchdog, threaded through
//! `quant::pipeline` as `Option<&mut RunObserver>` so the telemetry-off
//! path stays byte-identical with zero clock reads.
//!
//! **Overhead budget:** with observability on (the default), the decode
//! hot path pays a handful of `Instant::now()` reads per tick (tick
//! granularity, not per-kernel), integer histogram records, and fixed-size
//! ring writes — no locks, no allocation, no formatting. All string work
//! happens at scrape/dump time on the HTTP worker. With it off, the cost
//! is a branch per phase.

pub mod hist;
pub mod profile;
pub mod prometheus;
pub mod run;
pub mod trace;

pub use hist::{Histogram, NBUCKETS};
pub use profile::{Phase, TickProfiler, ALL_PHASES, NPHASES};
pub use prometheus::{escape_label_value, valid_label_name, valid_metric_name, Registry};
pub use run::{EventSink, RunAborted, RunObserver, Watchdog};
pub use trace::{reason_str, TraceEvent, TraceKind, TraceRing};
