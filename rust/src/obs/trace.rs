//! Per-request trace spans and the flight recorder.
//!
//! Every request gets tick-stamped lifecycle events (submitted → queued →
//! admitted/shed/expired → prefill start/end → first token → finished)
//! pushed into a bounded ring of fixed-size [`TraceEvent`]s.
//!
//! **Custody model:** the ring is owned by the engine, which is owned by
//! one bridge thread, and every reader (trace query, flight-recorder dump)
//! arrives as a bridge command serviced at a tick boundary — so the ring
//! needs no locks and no atomics. "Lock-free" here is by construction
//! (single-owner), not by CAS loops: the cheapest synchronization is the
//! one the architecture already paid for.
//!
//! **Allocation model:** the buffer is reserved up front
//! ([`TraceRing::new`]); `push` writes into spare capacity until full and
//! then overwrites in place, so the steady-state decode path records
//! events without ever touching the allocator. Events are `Copy` structs
//! of integers — no strings, no boxing.
//!
//! **Flight recorder:** when something goes wrong (an overload collapse, a
//! stall), the last [`TraceRing::capacity`] events are still in the ring
//! and can be dumped post-mortem as Chrome-trace-format JSON
//! ([`TraceRing::chrome_events`], one JSON object per line over HTTP) and
//! loaded into `chrome://tracing` / Perfetto.

use crate::util::json::Json;

/// Lifecycle event kinds, in the order a healthy request emits them.
/// `Finished` is the single terminal kind — exactly one per submitted
/// request, whatever path it took (completion, stop token, shed, queued
/// deadline, cancellation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Request entered the admission queue. `arg` = prompt tokens.
    Submitted,
    /// Request was still waiting at the end of a tick (emitted once, the
    /// first time it waits). `arg` = 0.
    Deferred,
    /// Request was admitted to a slot. `arg` = prefix-cache hit tokens
    /// (0 on a cold miss or with caching off).
    Admitted,
    /// First prefill chunk for this slot ran this tick. `arg` = prompt
    /// tokens left to run (after any prefix-cache resume).
    PrefillStart,
    /// Prefill finished; decode starts next tick. `arg` = total prompt
    /// tokens committed (prefilled plus cache-resumed).
    PrefillEnd,
    /// First generated token was sampled. `arg` = 0.
    FirstToken,
    /// Terminal event. `arg` = finish-reason code ([`reason_str`]).
    Finished,
}

impl TraceKind {
    /// Stable snake_case name used in trace JSON and Chrome-trace output.
    pub fn as_str(self) -> &'static str {
        match self {
            TraceKind::Submitted => "submitted",
            TraceKind::Deferred => "deferred",
            TraceKind::Admitted => "admitted",
            TraceKind::PrefillStart => "prefill_start",
            TraceKind::PrefillEnd => "prefill_end",
            TraceKind::FirstToken => "first_token",
            TraceKind::Finished => "finished",
        }
    }
}

/// Finish-reason codes carried in [`TraceKind::Finished`] events. The
/// strings match the machine-readable `"reason"` slugs the HTTP gateway
/// already emits, so a trace and an error body agree.
pub fn reason_str(code: u64) -> &'static str {
    match code {
        0 => "max_new",
        1 => "stop",
        2 => "cancelled",
        3 => "shed",
        4 => "deadline_exceeded",
        _ => "unknown",
    }
}

/// One fixed-size lifecycle event: plain integers only, `Copy`, no heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Engine tick counter when the event was recorded (submissions land
    /// between ticks and carry the upcoming tick's number).
    pub tick: u64,
    /// Monotonic seconds since the engine started (an `Instant` delta —
    /// never wall-clock).
    pub t_s: f64,
    /// The request this event belongs to.
    pub id: u64,
    pub kind: TraceKind,
    /// Kind-specific argument; see [`TraceKind`] variant docs.
    pub arg: u64,
}

impl TraceEvent {
    fn to_json(self) -> Json {
        let j = Json::obj()
            .set("tick", self.tick)
            .set("t_s", self.t_s)
            .set("kind", self.kind.as_str())
            .set("arg", self.arg);
        if self.kind == TraceKind::Finished {
            j.set("reason", reason_str(self.arg))
        } else {
            j
        }
    }
}

/// Bounded single-owner ring of recent [`TraceEvent`]s. See the module
/// docs for the custody and allocation model.
#[derive(Clone, Debug)]
pub struct TraceRing {
    enabled: bool,
    /// Backing store: reserved to `cap` at construction, grows by `push`
    /// into spare capacity (never reallocates), then wraps.
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Next write position once the ring has wrapped (`buf.len() == cap`).
    head: usize,
    /// Total events ever pushed (so readers can report drops).
    pushed: u64,
}

impl TraceRing {
    /// A ring holding the most recent `cap` events (`cap >= 1`). All
    /// backing memory is allocated here, up front.
    pub fn new(cap: usize, enabled: bool) -> TraceRing {
        let cap = cap.max(1);
        TraceRing { enabled, buf: Vec::with_capacity(cap), cap, head: 0, pushed: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events pushed since construction/reset; `pushed() - len()`
    /// events have been overwritten.
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Record an event. No-op when disabled; never allocates (capacity is
    /// reserved at construction).
    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
        }
        self.pushed += 1;
    }

    /// Iterate oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        let (tail, head) = self.buf.split_at(self.head);
        head.iter().chain(tail.iter())
    }

    /// Build the span tree for one request from whatever of its events are
    /// still in the ring. Returns `None` if the ring holds no events for
    /// `id` (unknown, or already overwritten).
    ///
    /// The tree has three derived spans over the raw event list:
    /// `queued` (submitted → admitted/terminal), `prefill` (prefill_start →
    /// prefill_end, annotated with the prefix-cache hit length from the
    /// admission event), and `decode` (prefill_end → finished, annotated
    /// with time-to-first-token).
    pub fn span_tree(&self, id: u64) -> Option<Json> {
        let evs: Vec<&TraceEvent> = self.iter().filter(|e| e.id == id).collect();
        if evs.is_empty() {
            return None;
        }
        let at = |k: TraceKind| evs.iter().find(|e| e.kind == k);
        let submitted = at(TraceKind::Submitted);
        let admitted = at(TraceKind::Admitted);
        let prefill_start = at(TraceKind::PrefillStart);
        let prefill_end = at(TraceKind::PrefillEnd);
        let first_token = at(TraceKind::FirstToken);
        let finished = at(TraceKind::Finished);
        let terminal_t = finished.map(|e| e.t_s);

        let mut spans = Vec::new();
        if let Some(s) = submitted {
            let end = admitted.map(|e| e.t_s).or(terminal_t);
            let mut span = Json::obj().set("name", "queued").set("start_s", s.t_s);
            if let Some(end) = end {
                span.insert("end_s", end);
            }
            spans.push(span);
        }
        if let Some(ps) = prefill_start {
            let mut span = Json::obj().set("name", "prefill").set("start_s", ps.t_s);
            span.insert("run_tokens", ps.arg);
            if let Some(a) = admitted {
                span.insert("prefix_hit_tokens", a.arg);
            }
            if let Some(pe) = prefill_end {
                span.insert("end_s", pe.t_s);
            }
            spans.push(span);
        }
        if let Some(pe) = prefill_end {
            let mut span = Json::obj().set("name", "decode").set("start_s", pe.t_s);
            if let Some(ft) = first_token {
                span.insert("first_token_s", ft.t_s);
            }
            if let Some(end) = terminal_t {
                span.insert("end_s", end);
            }
            spans.push(span);
        }

        let mut doc = Json::obj()
            .set("id", id)
            .set("events", Json::Arr(evs.iter().map(|e| e.to_json()).collect()))
            .set("spans", Json::Arr(spans));
        if let Some(f) = finished {
            doc.insert("finish_reason", reason_str(f.arg));
        }
        Some(doc)
    }

    /// Render the whole ring as Chrome-trace-format event objects (oldest
    /// first): one `"ph": "i"` instant event per lifecycle event, with the
    /// request id as the `tid` so chrome://tracing groups each request on
    /// its own track. Timestamps are microseconds, per the format.
    pub fn chrome_events(&self) -> Vec<Json> {
        self.iter()
            .map(|e| {
                let args = {
                    let a = Json::obj().set("tick", e.tick).set("arg", e.arg);
                    if e.kind == TraceKind::Finished {
                        a.set("reason", reason_str(e.arg))
                    } else {
                        a
                    }
                };
                Json::obj()
                    .set("name", e.kind.as_str())
                    .set("ph", "i")
                    .set("s", "t")
                    .set("ts", e.t_s * 1e6)
                    .set("pid", 1u64)
                    .set("tid", e.id)
                    .set("args", args)
            })
            .collect()
    }

    /// Drop all events (engine `reset`), keeping capacity and flag.
    pub fn reset(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.pushed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, kind: TraceKind, t_s: f64, arg: u64) -> TraceEvent {
        TraceEvent { tick: (t_s * 1000.0) as u64, t_s, id, kind, arg }
    }

    #[test]
    fn ring_wraps_without_reallocating() {
        let mut r = TraceRing::new(4, true);
        let cap_ptr = r.buf.as_ptr();
        for i in 0..10u64 {
            r.push(ev(i, TraceKind::Submitted, i as f64, 0));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.pushed(), 10);
        assert_eq!(r.buf.as_ptr(), cap_ptr, "ring must never reallocate");
        let ids: Vec<u64> = r.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "oldest -> newest after wrap");
    }

    #[test]
    fn disabled_ring_records_nothing() {
        let mut r = TraceRing::new(4, false);
        r.push(ev(1, TraceKind::Submitted, 0.0, 0));
        assert!(r.is_empty());
        assert_eq!(r.pushed(), 0);
        assert!(r.span_tree(1).is_none());
    }

    #[test]
    fn span_tree_covers_the_happy_path() {
        let mut r = TraceRing::new(64, true);
        r.push(ev(7, TraceKind::Submitted, 0.001, 12));
        r.push(ev(7, TraceKind::Admitted, 0.002, 4));
        r.push(ev(7, TraceKind::PrefillStart, 0.003, 8));
        r.push(ev(7, TraceKind::PrefillEnd, 0.004, 8));
        r.push(ev(7, TraceKind::FirstToken, 0.005, 0));
        r.push(ev(7, TraceKind::Finished, 0.010, 0));
        r.push(ev(8, TraceKind::Submitted, 0.011, 3));
        let t = r.span_tree(7).expect("known id");
        assert_eq!(t.get("id").and_then(|j| j.as_f64()), Some(7.0));
        assert_eq!(t.get("finish_reason").and_then(|j| j.as_str()), Some("max_new"));
        let spans = t.get("spans").and_then(|j| j.as_arr()).unwrap();
        let names: Vec<&str> =
            spans.iter().map(|s| s.get("name").and_then(|j| j.as_str()).unwrap()).collect();
        assert_eq!(names, vec!["queued", "prefill", "decode"]);
        let prefill = &spans[1];
        assert_eq!(prefill.get("prefix_hit_tokens").and_then(|j| j.as_f64()), Some(4.0));
        let decode = &spans[2];
        assert_eq!(decode.get("first_token_s").and_then(|j| j.as_f64()), Some(0.005));
        assert_eq!(decode.get("end_s").and_then(|j| j.as_f64()), Some(0.010));
        // Events for id 8 don't leak into id 7's tree.
        assert_eq!(t.get("events").and_then(|j| j.as_arr()).unwrap().len(), 6);
        assert!(r.span_tree(99).is_none());
    }

    #[test]
    fn shed_request_gets_a_terminal_only_tree() {
        let mut r = TraceRing::new(8, true);
        r.push(ev(3, TraceKind::Submitted, 0.001, 5));
        r.push(ev(3, TraceKind::Finished, 0.002, 3)); // shed
        let t = r.span_tree(3).unwrap();
        assert_eq!(t.get("finish_reason").and_then(|j| j.as_str()), Some("shed"));
        let spans = t.get("spans").and_then(|j| j.as_arr()).unwrap();
        // Only the queued span exists, closed by the terminal event.
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("end_s").and_then(|j| j.as_f64()), Some(0.002));
    }

    #[test]
    fn chrome_events_parse_and_carry_required_fields() {
        let mut r = TraceRing::new(8, true);
        r.push(ev(1, TraceKind::Submitted, 0.5, 10));
        r.push(ev(1, TraceKind::Finished, 1.5, 4));
        let evs = r.chrome_events();
        assert_eq!(evs.len(), 2);
        for line in &evs {
            // Each event must survive a serialize → parse round trip (the
            // HTTP dump emits one per NDJSON line).
            let back = Json::parse(&line.to_string()).expect("valid JSON");
            assert!(back.get("name").is_some());
            assert_eq!(back.get("ph").and_then(|j| j.as_str()), Some("i"));
            assert!(back.get("ts").and_then(|j| j.as_f64()).is_some());
            assert!(back.get("tid").and_then(|j| j.as_f64()).is_some());
        }
        assert_eq!(evs[1].get("args").and_then(|a| a.get("reason")).and_then(|j| j.as_str()),
            Some("deadline_exceeded"));
    }

    #[test]
    fn reason_strings_match_the_gateway_slugs() {
        assert_eq!(reason_str(0), "max_new");
        assert_eq!(reason_str(1), "stop");
        assert_eq!(reason_str(2), "cancelled");
        assert_eq!(reason_str(3), "shed");
        assert_eq!(reason_str(4), "deadline_exceeded");
        assert_eq!(reason_str(99), "unknown");
    }
}
