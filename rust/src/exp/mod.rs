//! Experiment drivers — one per paper table/figure (see DESIGN.md §6).
//!
//! Every driver prints a paper-formatted table and writes
//! `results/<id>.{json,md}`. `run("all", …)` regenerates the full set.

pub mod ablations;
pub mod accuracy;
pub mod kernels;
pub mod resources;
pub mod serving;
pub mod sizes;
pub mod zoo;

use crate::util::cli::Args;
use crate::util::json::{write_json, Json};
use crate::util::tables::Table;

/// Shared experiment context.
pub struct Ctx {
    pub checkpoints: String,
    pub results: String,
    pub quick: bool,
    pub seed: u64,
}

impl Ctx {
    pub fn from_args(args: &Args) -> Ctx {
        Ctx {
            checkpoints: args.get_or("checkpoints", "checkpoints").to_string(),
            results: args.get_or("results", "results").to_string(),
            quick: args.flag("quick"),
            seed: args.get_u64("seed", 0),
        }
    }

    /// Persist a table + raw JSON under results/.
    pub fn save(&self, id: &str, table: &Table, raw: Json) {
        table.print();
        table.write(&format!("{}/{id}.md", self.results)).expect("write md");
        write_json(&format!("{}/{id}.json", self.results), &raw).expect("write json");
        eprintln!("[exp] saved results/{id}.{{md,json}}");
    }
}

/// Dispatch an experiment by id.
pub fn run(id: &str, ctx: &Ctx) {
    match id {
        "zoo" => zoo::build_zoo(&ctx.checkpoints, true),
        "table2" => accuracy::table2(ctx),
        "table3" => accuracy::table3(ctx),
        "fig1" => accuracy::fig1(ctx),
        "fig6" => accuracy::fig6(ctx),
        "table4" => resources::table4(ctx),
        "table7" => resources::table7(ctx),
        "table8" => resources::table8(ctx),
        "table5" => ablations::table5(ctx),
        "table6" => ablations::table6(ctx),
        "table9" => ablations::table9(ctx),
        "table10" => ablations::table10(ctx),
        "fig8" => ablations::fig8(ctx),
        "fig9" => ablations::fig9(ctx),
        "table12" => serving::table12(ctx),
        "fig4" | "fig5" | "fig4_5" => serving::fig4_5(ctx),
        "fig7" => serving::fig7(ctx),
        "table15" => serving::table15(ctx),
        "streaming" => serving::streaming(ctx),
        "fig10" | "fig11" | "fig12" | "fig13" | "fig10_13" => kernels::fig10_13(ctx),
        "table13" | "table14" | "table13_14" => sizes::table13_14(ctx),
        "all" => {
            zoo::build_zoo(&ctx.checkpoints, true);
            for id in [
                "table13_14", "fig10_13", "table2", "fig1", "fig6", "table3", "table5",
                "table6", "table9", "table10", "fig8", "fig9", "table4", "table7", "table8",
                "table12", "fig4_5", "fig7", "table15", "streaming",
            ] {
                eprintln!("\n=== exp {id} ===");
                run(id, ctx);
            }
        }
        other => panic!("unknown experiment '{other}' (see DESIGN.md §6)"),
    }
}
