//! Tables 13–14 — model sizes and BPW bounds for the published models.
//! Purely analytic (Appendix F formulas + public model dimensions), so
//! this reproduction is *exact* up to the paper's own rounding.

use super::Ctx;
use crate::quant::bpw::{
    arbllm_rc_bits, billm_bits, hbllm_col_bits, hbllm_row_bits, model_specs, nanoquant_bits,
    stbllm_bits,
};
use crate::quant::rank_for_bpw;
use crate::util::json::Json;
use crate::util::tables::Table;

const C: usize = 50; // salient-column cap of the open-source baselines
const K: usize = 128; // scale block size

pub fn table13_14(ctx: &Ctx) {
    let mut t13 = Table::new(
        "Table 13 — quantized model sizes (GB)",
        &[
            "Model",
            "BF16",
            "NanoQuant@1",
            "BiLLM",
            "STBLLM4:8",
            "STBLLM6:8",
            "ARB-LLM_RC",
            "HBLLM_row",
            "HBLLM_col",
        ],
    );
    let mut t14 = Table::new(
        "Table 14 — effective bits per weight (decoder linears)",
        &[
            "Model",
            "NanoQuant@1",
            "BiLLM",
            "STBLLM4:8",
            "STBLLM6:8",
            "ARB-LLM_RC",
            "HBLLM_row",
            "HBLLM_col",
        ],
    );
    let mut raw = Json::obj();
    for spec in model_specs() {
        let nq = |n: usize, m: usize| nanoquant_bits(n, m, rank_for_bpw(n, m, 1.0));
        let billm = |n: usize, m: usize| billm_bits(n, m, C, K);
        let stb48 = |n: usize, m: usize| stbllm_bits(n, m, C, K, 4, 8);
        let stb68 = |n: usize, m: usize| stbllm_bits(n, m, C, K, 6, 8);
        let arb = |n: usize, m: usize| arbllm_rc_bits(n, m, C, K);
        let hbr = |n: usize, m: usize| hbllm_row_bits(n, m, C, K);
        let hbc = |n: usize, m: usize| hbllm_col_bits(n, m, K);

        let gb = |f: &dyn Fn(usize, usize) -> usize| spec.quantized_bytes(f) / 1e9;
        t13.row(vec![
            spec.name.to_string(),
            format!("{:.2}", spec.bf16_bytes() / 1e9),
            format!("{:.2}", gb(&nq)),
            format!("{:.2}", gb(&billm)),
            format!("{:.2}", gb(&stb48)),
            format!("{:.2}", gb(&stb68)),
            format!("{:.2}", gb(&arb)),
            format!("{:.2}", gb(&hbr)),
            format!("{:.2}", gb(&hbc)),
        ]);
        let bpw = |f: &dyn Fn(usize, usize) -> usize| spec.bpw(f);
        t14.row(vec![
            spec.name.to_string(),
            format!("{:.2}", bpw(&nq)),
            format!("{:.2}", bpw(&billm)),
            format!("{:.2}", bpw(&stb48)),
            format!("{:.2}", bpw(&stb68)),
            format!("{:.2}", bpw(&arb)),
            format!("{:.2}", bpw(&hbr)),
            format!("{:.2}", bpw(&hbc)),
        ]);
        raw.insert(
            spec.name,
            Json::obj()
                .set("bf16_gb", spec.bf16_bytes() / 1e9)
                .set("nanoquant_gb", gb(&nq))
                .set("nanoquant_bpw", bpw(&nq))
                .set("billm_bpw", bpw(&billm))
                .set("arb_bpw", bpw(&arb))
                .set("hbllm_row_bpw", bpw(&hbr)),
        );
    }
    t14.print();
    t14.write(&format!("{}/table14.md", ctx.results)).ok();
    ctx.save("table13", &t13, raw);
}
