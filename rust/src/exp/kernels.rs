//! Kernel micro-benchmarks: Figs. 10–13 (GEMV/GEMM across shapes, ours vs
//! GemLite-like naive-unpack vs dense, native engines and PJRT artifacts).

use super::Ctx;
use crate::quant::kernels::{NaiveUnpackLinear, PackedLinear};
use crate::quant::{rank_for_bpw, LatentFactors};
use crate::runtime::{literal_f32, packed_literal, vec_literal, Runtime};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tables::Table;
use crate::util::timer::bench;

pub const SHAPES: &[(usize, usize)] = &[(256, 256), (512, 512), (1024, 1024)];

pub fn make_packed(n: usize, m: usize, r: usize, seed: u64) -> crate::quant::QuantLinear {
    let mut rng = Rng::new(seed);
    LatentFactors {
        u: Tensor::randn(&[n, r], 1.0, &mut rng),
        v: Tensor::randn(&[m, r], 1.0, &mut rng),
        s1: (0..n).map(|_| rng.uniform_in(0.2, 2.0)).collect(),
        s2: (0..m).map(|_| rng.uniform_in(0.2, 2.0)).collect(),
    }
    .freeze()
}

pub fn fig10_13(ctx: &Ctx) {
    let mut table = Table::new(
        "Figs. 10-13 — packed binary GEMV/GEMM kernels across shapes and engines",
        &["Kernel", "Shape", "Engine", "ms/op", "ops/s", "Eff. MB"],
    );
    let mut raw = Json::obj();
    let min_t = if ctx.quick { 0.05 } else { 0.2 };
    let iters = if ctx.quick { 20 } else { 200 };

    // --- Native Rust engines (Fig. 10 GEMV shape sweep; Fig. 12-13 engines) ---
    for &(n, m) in SHAPES {
        let r = rank_for_bpw(n, m, 1.0);
        let q = make_packed(n, m, r, ctx.seed);
        let mut rng = Rng::new(ctx.seed ^ 1);
        let x = rng.normal_vec(m, 1.0);
        // Decode-hot-path form: preallocated output, `matvec_into` only.
        let mut y = vec![0.0f32; n];

        use crate::nn::decode::MatVec;
        let packed = PackedLinear::new(q.clone());
        let st = bench(&format!("gemv {n}x{m} packed"), min_t, iters, || {
            packed.matvec_into(&x, &mut y);
            std::hint::black_box(&y);
        });
        let mb = q.effective_bits() / 8_000_000;
        push_row(&mut table, &mut raw, "GEMV", n, m, "packed (ours)", &st, mb);

        let naive = NaiveUnpackLinear { q: q.clone() };
        let st = bench(&format!("gemv {n}x{m} naive"), min_t, iters.min(40), || {
            naive.matvec_into(&x, &mut y);
            std::hint::black_box(&y);
        });
        push_row(&mut table, &mut raw, "GEMV", n, m, "naive-unpack (GemLite-like)", &st, mb);

        let dense = q.reconstruct();
        let st = bench(&format!("gemv {n}x{m} dense"), min_t, iters, || {
            dense.matvec_into(&x, &mut y);
            std::hint::black_box(&y);
        });
        let dense_mb = dense.numel() * 4 / 1_000_000;
        push_row(&mut table, &mut raw, "GEMV", n, m, "dense f32", &st, dense_mb);

        // Batched GEMM (Fig. 11): batch 8.
        let xb = Tensor::randn(&[8, m], 1.0, &mut rng);
        let st = bench(&format!("gemm {n}x{m} packed b8"), min_t, iters / 4, || {
            std::hint::black_box(packed.forward_batch(&xb));
        });
        push_row(&mut table, &mut raw, "GEMM-b8", n, m, "packed (ours)", &st, mb);
        let st = bench(&format!("gemm {n}x{m} dense b8"), min_t, iters / 4, || {
            std::hint::black_box(crate::tensor::matmul_a_bt(&xb, &dense));
        });
        push_row(&mut table, &mut raw, "GEMM-b8", n, m, "dense f32", &st, dense_mb);
    }

    // --- PJRT artifact engines (the L1 Pallas kernels through XLA) ---
    match Runtime::new("artifacts") {
        Ok(rt) if !rt.can_execute() => {
            eprintln!("[fig10_13] no pjrt backend in this build; skipping PJRT rows");
        }
        Err(e) => {
            eprintln!("[fig10_13] {e}; skipping PJRT rows");
        }
        Ok(mut rt) => {
            for &(n, m) in SHAPES {
                let r = rank_for_bpw(n, m, 1.0);
                let q = make_packed(n, m, r, ctx.seed);
                let mut rng = Rng::new(ctx.seed ^ 2);
                let x = rng.normal_vec(m, 1.0);
                for engine in ["pallas", "naive"] {
                    let name = format!("gemv_{n}x{m}x{r}_{engine}");
                    if rt.load(&name).is_err() {
                        continue;
                    }
                    let args = vec![
                        packed_literal(&q.u).unwrap(),
                        packed_literal(&q.vt).unwrap(),
                        vec_literal(&q.s1),
                        vec_literal(&q.s2),
                        vec_literal(&x),
                    ];
                    let st = bench(&name, min_t, iters.min(30), || {
                        let out = rt.execute(&name, &args).unwrap();
                        std::hint::black_box(literal_f32(&out[0]).unwrap());
                    });
                    push_row(
                        &mut table,
                        &mut raw,
                        "GEMV-pjrt",
                        n,
                        m,
                        &format!("{engine} (XLA)"),
                        &st,
                        q.effective_bits() / 8_000_000,
                    );
                }
            }
        }
    }
    ctx.save("fig10_13", &table, raw);
}

fn push_row(
    table: &mut Table,
    raw: &mut Json,
    kernel: &str,
    n: usize,
    m: usize,
    engine: &str,
    st: &crate::util::timer::BenchStats,
    eff_mb: usize,
) {
    table.row(vec![
        kernel.into(),
        format!("{n}x{m}"),
        engine.into(),
        format!("{:.3}", st.mean_s * 1e3),
        format!("{:.1}", 1.0 / st.mean_s),
        format!("{eff_mb}"),
    ]);
    raw.insert(
        &format!("{kernel}/{n}x{m}/{engine}"),
        Json::obj().set("mean_ms", st.mean_s * 1e3).set("p50_ms", st.p50_s * 1e3),
    );
}
