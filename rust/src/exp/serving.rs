//! Serving experiments: Table 12 (throughput vs sequence length), Figs. 4–5
//! (consumer / datacenter efficiency), Fig. 7 (decode vs output length),
//! Table 15 (qualitative generations).
//!
//! Each experiment reports two layers of evidence (DESIGN.md §2):
//! *measured* wall-clock from the real Rust engines (relative kernel
//! ordering on this CPU), and *device-model* estimates (bandwidth-roofline
//! on the paper's GPUs with the real published model sizes).

use super::accuracy::{nanoquant_run, prepare};
use super::Ctx;
use crate::quant::bpw::model_specs;
use crate::quant::Engine;
use crate::serve::device::{estimate_decode, H100, RTX_3050};
use crate::serve::{Request, Server, ServerConfig};
use crate::util::json::Json;
use crate::util::tables::Table;

/// KV bytes per token for a published spec at FP16.
fn kv_bytes_per_pos(spec: &crate::quant::bpw::ModelSpec) -> usize {
    2 * spec.layers * spec.kv_dim * 2 // K and V, fp16
}

// ---------------------------------------------------------------------------
// Table 12 — throughput / peak memory vs sequence length @0.55 bits.
// ---------------------------------------------------------------------------

pub fn table12(ctx: &Ctx) {
    let mut table = Table::new(
        "Table 12 — decode throughput & peak memory vs context (RTX 3050 device model, 0.55-bit NanoQuant; plus measured CPU engine on in-repo analogues)",
        &["Model", "Metric", "32", "64", "128", "256", "512", "1024"],
    );
    let mut raw = Json::obj();
    let lens = [32usize, 64, 128, 256, 512, 1024];

    // Device-model rows with the real Llama-2 shapes (the paper's table).
    for name in ["L2-7", "L2-13", "L2-70"] {
        let spec = model_specs().into_iter().find(|s| s.name == name).unwrap();
        let weight_bytes = spec.nanoquant_bytes(0.55) as usize;
        let mut tok_row = vec![name.to_string(), "Tokens/s".to_string()];
        let mut mem_row = vec![name.to_string(), "Peak Mem (GB)".to_string()];
        let mut j = Json::obj();
        for &len in &lens {
            let kv = kv_bytes_per_pos(&spec) * len;
            let est = estimate_decode(&RTX_3050, weight_bytes, kv, 50_000_000);
            tok_row.push(format!("{:.2}", est.tokens_per_s));
            mem_row.push(format!("{:.2}", est.peak_mem_gb));
            j.insert(
                &len.to_string(),
                Json::obj().set("tok_s", est.tokens_per_s).set("mem_gb", est.peak_mem_gb),
            );
        }
        table.row(tok_row);
        table.row(mem_row);
        raw.insert(name, j);
    }

    // Measured rows: in-repo analogues on the real packed engine (CPU).
    let sizes = if ctx.quick { vec![("l2", "xs")] } else { vec![("l2", "xs"), ("l2", "s")] };
    for (family, size) in sizes {
        let p = prepare(ctx, family, size);
        let (qm, _, _) = nanoquant_run(ctx, &p, 0.55);
        let dm = qm.to_decode_model(Engine::Packed);
        let mut row = vec![format!("{family}-{size} (measured)"), "Tokens/s".to_string()];
        let mut j = Json::obj();
        for &len in &lens {
            if len > dm.cfg.max_seq {
                row.push("-".into());
                continue;
            }
            let mut server = Server::new(
                qm.to_decode_model(Engine::Packed),
                ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
            );
            let prompt: Vec<u16> =
                (0..len.min(dm.cfg.max_seq - 17)).map(|i| (i % 250) as u16).collect();
            server.run(vec![Request::greedy(0, prompt, 16)]);
            row.push(format!("{:.1}", server.metrics.tokens_per_s));
            j.insert(&len.to_string(), server.metrics.tokens_per_s);
        }
        table.row(row);
        raw.insert(&format!("{family}-{size}-measured"), j);
    }
    ctx.save("table12", &table, raw);
}

// ---------------------------------------------------------------------------
// Figs. 4–5 — consumer and datacenter efficiency vs BF16.
// ---------------------------------------------------------------------------

pub fn fig4_5(ctx: &Ctx) {
    let mut table = Table::new(
        "Figs. 4-5 — decode throughput / peak memory / energy: NanoQuant (1 bit) vs BF16 (device model on published model shapes + measured engine ratios)",
        &["Device", "Model", "Engine", "Tokens/s", "Peak Mem (GB)", "J/token", "Speedup"],
    );
    let mut raw = Json::obj();

    // Device-model section (Fig. 4: RTX 3050 w/ L3-1/L3-3; Fig. 5: H100 w/ L2-13, Q3-14).
    let cases = [
        (&RTX_3050, "L3-1"),
        (&RTX_3050, "L3-3"),
        (&H100, "L2-13"),
        (&H100, "Q3-14"),
    ];
    for (dev, name) in cases {
        let spec = model_specs().into_iter().find(|s| s.name == name).unwrap();
        let kv = kv_bytes_per_pos(&spec) * 256;
        let dense = estimate_decode(dev, spec.bf16_bytes() as usize, kv, 50_000_000);
        let quant = estimate_decode(dev, spec.nanoquant_bytes(1.0) as usize, kv, 50_000_000);
        let speedup = quant.tokens_per_s / dense.tokens_per_s;
        for (engine, est) in [("BF16", &dense), ("NanoQuant", &quant)] {
            table.row(vec![
                dev.name.into(),
                name.into(),
                engine.into(),
                format!("{:.2}", est.tokens_per_s),
                format!("{:.2}", est.peak_mem_gb),
                format!("{:.4}", est.energy_per_token_j),
                if engine == "NanoQuant" { format!("{speedup:.2}x") } else { "1.00x".into() },
            ]);
        }
        raw.insert(
            &format!("{}/{}", dev.name, name),
            Json::obj()
                .set("speedup", speedup)
                .set("mem_ratio", dense.peak_mem_gb / quant.peak_mem_gb)
                .set("energy_ratio", dense.energy_per_token_j / quant.energy_per_token_j),
        );
    }

    // Measured section: real engines on the in-repo model.
    let p = prepare(ctx, "l2", "s");
    let (qm, _, _) = nanoquant_run(ctx, &p, 1.0);
    let prompt: Vec<u16> = (0..16).map(|i| (i * 3 % 250) as u16).collect();
    let mut measured = Json::obj();
    let mut tok_s = std::collections::BTreeMap::new();
    for (engine, label) in [(Engine::Dense, "dense f32"), (Engine::Packed, "packed (ours)")] {
        let mut server = Server::new(
            qm.to_decode_model(engine),
            ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
        );
        server.run(vec![Request::greedy(0, prompt.clone(), 48)]);
        tok_s.insert(label, server.metrics.tokens_per_s);
        table.row(vec![
            "CPU (measured)".into(),
            "l2-s".into(),
            label.into(),
            format!("{:.1}", server.metrics.tokens_per_s),
            format!("{:.4}", server.metrics.weight_bytes as f64 / 1e9),
            "-".into(),
            "-".into(),
        ]);
        measured.insert(label, server.metrics.tokens_per_s);
    }
    raw.insert("measured", measured);
    ctx.save("fig4_5", &table, raw);
}

// ---------------------------------------------------------------------------
// Fig. 7 — decode vs output length, engines incl. VQ comparator.
// ---------------------------------------------------------------------------

pub fn fig7(ctx: &Ctx) {
    let p = prepare(ctx, "l2", "s");
    let (qm, report, _) = nanoquant_run(ctx, &p, 1.0);
    let out_lens = if ctx.quick { vec![8usize, 16] } else { vec![8usize, 16, 32, 64] };
    let mut table = Table::new(
        "Fig. 7 — measured decode wall-clock vs output length (128-token prompt analogue: 16 tokens)",
        &["Engine", "Out len", "Tokens/s", "Weight MB"],
    );
    let mut raw = Json::obj();
    for (engine, label) in [
        (Engine::Dense, "BF16-like dense"),
        (Engine::Packed, "NanoQuant packed"),
        (Engine::NaiveUnpack, "VQ/dequant-like"),
    ] {
        let mut j = Json::obj();
        for &ol in &out_lens {
            let mut server = Server::new(
                qm.to_decode_model(engine),
                ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
            );
            let prompt: Vec<u16> = (0..16).map(|i| (i * 7 % 250) as u16).collect();
            server.run(vec![Request::greedy(0, prompt, ol)]);
            table.row(vec![
                label.into(),
                ol.to_string(),
                format!("{:.1}", server.metrics.tokens_per_s),
                format!("{:.2}", server.metrics.weight_bytes as f64 / 1e6),
            ]);
            j.insert(&ol.to_string(), server.metrics.tokens_per_s);
        }
        raw.insert(label, j);
    }
    raw.insert("model_bpw", report.effective_bpw);
    ctx.save("fig7", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 15 — qualitative generations at 1.0 / 0.8 / 0.55 bits.
// ---------------------------------------------------------------------------

pub fn table15(ctx: &Ctx) {
    let p = prepare(ctx, "l2", "s");
    let prompt_text = "the robin is";
    let mut table = Table::new(
        "Table 15 — qualitative continuations (prompt: 'the robin is')",
        &["Model", "Continuation"],
    );
    let mut raw = Json::obj();
    let gen = |dm: crate::nn::decode::DecodeModel| -> String {
        let mut server =
            Server::new(dm, ServerConfig { max_batch: 1, seed: ctx.seed, ..Default::default() });
        let reqs = vec![Request {
            id: 0,
            prompt: crate::data::tokenize(prompt_text),
            max_new: 48,
            temperature: 0.8,
            top_k: 32,
        }];
        server.run(reqs)[0].text.clone()
    };
    let teacher_dm = crate::nn::decode::dense_decode_model(&p.teacher);
    let text = gen(teacher_dm);
    table.row(vec!["FP teacher".into(), text.clone()]);
    raw.insert("fp", text);
    for bpw in [1.0, 0.8, 0.55] {
        let (qm, _, _) = nanoquant_run(ctx, &p, bpw);
        let text = gen(qm.to_decode_model(Engine::Packed));
        table.row(vec![format!("{bpw:.2}-bit NanoQuant"), text.clone()]);
        raw.insert(&format!("bpw{bpw}"), text);
    }
    ctx.save("table15", &table, raw);
}
