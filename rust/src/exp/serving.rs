//! Serving experiments: Table 12 (throughput vs sequence length), Figs. 4–5
//! (consumer / datacenter efficiency), Fig. 7 (decode vs output length),
//! Table 15 (qualitative generations).
//!
//! Each experiment reports two layers of evidence (DESIGN.md §2):
//! *measured* wall-clock from the real Rust engines (relative kernel
//! ordering on this CPU), and *device-model* estimates (bandwidth-roofline
//! on the paper's GPUs with the real published model sizes).

use super::accuracy::{nanoquant_run, prepare};
use super::Ctx;
use crate::quant::bpw::model_specs;
use crate::quant::Engine;
use crate::serve::device::{estimate_decode, H100, RTX_3050};
use crate::serve::{Engine as ServeEngine, Event, FinishReason, Request, Server, ServerConfig};
use crate::util::json::Json;
use crate::util::tables::Table;

/// KV bytes per token for a published spec at FP16.
fn kv_bytes_per_pos(spec: &crate::quant::bpw::ModelSpec) -> usize {
    2 * spec.layers * spec.kv_dim * 2 // K and V, fp16
}

// ---------------------------------------------------------------------------
// Table 12 — throughput / peak memory vs sequence length @0.55 bits.
// ---------------------------------------------------------------------------

pub fn table12(ctx: &Ctx) {
    let mut table = Table::new(
        "Table 12 — decode throughput & peak memory vs context (RTX 3050 device model, 0.55-bit NanoQuant; plus measured CPU engine on in-repo analogues)",
        &["Model", "Metric", "32", "64", "128", "256", "512", "1024"],
    );
    let mut raw = Json::obj();
    let lens = [32usize, 64, 128, 256, 512, 1024];

    // Device-model rows with the real Llama-2 shapes (the paper's table).
    for name in ["L2-7", "L2-13", "L2-70"] {
        let spec = model_specs().into_iter().find(|s| s.name == name).unwrap();
        let weight_bytes = spec.nanoquant_bytes(0.55) as usize;
        let mut tok_row = vec![name.to_string(), "Tokens/s".to_string()];
        let mut mem_row = vec![name.to_string(), "Peak Mem (GB)".to_string()];
        let mut j = Json::obj();
        for &len in &lens {
            let kv = kv_bytes_per_pos(&spec) * len;
            let est = estimate_decode(&RTX_3050, weight_bytes, kv, 50_000_000);
            tok_row.push(format!("{:.2}", est.tokens_per_s));
            mem_row.push(format!("{:.2}", est.peak_mem_gb));
            j.insert(
                &len.to_string(),
                Json::obj().set("tok_s", est.tokens_per_s).set("mem_gb", est.peak_mem_gb),
            );
        }
        table.row(tok_row);
        table.row(mem_row);
        raw.insert(name, j);
    }

    // Measured rows: in-repo analogues on the real packed engine (CPU).
    let sizes = if ctx.quick { vec![("l2", "xs")] } else { vec![("l2", "xs"), ("l2", "s")] };
    for (family, size) in sizes {
        let p = prepare(ctx, family, size);
        let (qm, _, _) = nanoquant_run(ctx, &p, 0.55);
        let dm = qm.to_decode_model(Engine::Packed);
        let mut row = vec![format!("{family}-{size} (measured)"), "Tokens/s".to_string()];
        let mut j = Json::obj();
        for &len in &lens {
            if len > dm.cfg.max_seq {
                row.push("-".into());
                continue;
            }
            let mut server = Server::new(
                qm.to_decode_model(Engine::Packed),
                ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
            );
            let prompt: Vec<u16> =
                (0..len.min(dm.cfg.max_seq - 17)).map(|i| (i % 250) as u16).collect();
            server.run(vec![Request::greedy(0, prompt, 16)]);
            row.push(format!("{:.1}", server.metrics.tokens_per_s));
            j.insert(&len.to_string(), server.metrics.tokens_per_s);
        }
        table.row(row);
        raw.insert(&format!("{family}-{size}-measured"), j);
    }
    ctx.save("table12", &table, raw);
}

// ---------------------------------------------------------------------------
// Figs. 4–5 — consumer and datacenter efficiency vs BF16.
// ---------------------------------------------------------------------------

pub fn fig4_5(ctx: &Ctx) {
    let mut table = Table::new(
        "Figs. 4-5 — decode throughput / peak memory / energy: NanoQuant (1 bit) vs BF16 (device model on published model shapes + measured engine ratios)",
        &["Device", "Model", "Engine", "Tokens/s", "Peak Mem (GB)", "J/token", "Speedup"],
    );
    let mut raw = Json::obj();

    // Device-model section (Fig. 4: RTX 3050 w/ L3-1/L3-3; Fig. 5: H100 w/ L2-13, Q3-14).
    let cases = [
        (&RTX_3050, "L3-1"),
        (&RTX_3050, "L3-3"),
        (&H100, "L2-13"),
        (&H100, "Q3-14"),
    ];
    for (dev, name) in cases {
        let spec = model_specs().into_iter().find(|s| s.name == name).unwrap();
        let kv = kv_bytes_per_pos(&spec) * 256;
        let dense = estimate_decode(dev, spec.bf16_bytes() as usize, kv, 50_000_000);
        let quant = estimate_decode(dev, spec.nanoquant_bytes(1.0) as usize, kv, 50_000_000);
        let speedup = quant.tokens_per_s / dense.tokens_per_s;
        for (engine, est) in [("BF16", &dense), ("NanoQuant", &quant)] {
            table.row(vec![
                dev.name.into(),
                name.into(),
                engine.into(),
                format!("{:.2}", est.tokens_per_s),
                format!("{:.2}", est.peak_mem_gb),
                format!("{:.4}", est.energy_per_token_j),
                if engine == "NanoQuant" { format!("{speedup:.2}x") } else { "1.00x".into() },
            ]);
        }
        raw.insert(
            &format!("{}/{}", dev.name, name),
            Json::obj()
                .set("speedup", speedup)
                .set("mem_ratio", dense.peak_mem_gb / quant.peak_mem_gb)
                .set("energy_ratio", dense.energy_per_token_j / quant.energy_per_token_j),
        );
    }

    // Measured section: real engines on the in-repo model.
    let p = prepare(ctx, "l2", "s");
    let (qm, _, _) = nanoquant_run(ctx, &p, 1.0);
    let prompt: Vec<u16> = (0..16).map(|i| (i * 3 % 250) as u16).collect();
    let mut measured = Json::obj();
    let mut tok_s = std::collections::BTreeMap::new();
    for (engine, label) in [(Engine::Dense, "dense f32"), (Engine::Packed, "packed (ours)")] {
        let mut server = Server::new(
            qm.to_decode_model(engine),
            ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
        );
        server.run(vec![Request::greedy(0, prompt.clone(), 48)]);
        tok_s.insert(label, server.metrics.tokens_per_s);
        table.row(vec![
            "CPU (measured)".into(),
            "l2-s".into(),
            label.into(),
            format!("{:.1}", server.metrics.tokens_per_s),
            format!("{:.4}", server.metrics.weight_bytes as f64 / 1e9),
            "-".into(),
            "-".into(),
        ]);
        measured.insert(label, server.metrics.tokens_per_s);
    }
    raw.insert("measured", measured);
    ctx.save("fig4_5", &table, raw);
}

// ---------------------------------------------------------------------------
// Fig. 7 — decode vs output length, engines incl. VQ comparator.
// ---------------------------------------------------------------------------

pub fn fig7(ctx: &Ctx) {
    let p = prepare(ctx, "l2", "s");
    let (qm, report, _) = nanoquant_run(ctx, &p, 1.0);
    let out_lens = if ctx.quick { vec![8usize, 16] } else { vec![8usize, 16, 32, 64] };
    let mut table = Table::new(
        "Fig. 7 — measured decode wall-clock vs output length (128-token prompt analogue: 16 tokens)",
        &["Engine", "Out len", "Tokens/s", "Weight MB"],
    );
    let mut raw = Json::obj();
    for (engine, label) in [
        (Engine::Dense, "BF16-like dense"),
        (Engine::Packed, "NanoQuant packed"),
        (Engine::NaiveUnpack, "VQ/dequant-like"),
    ] {
        let mut j = Json::obj();
        for &ol in &out_lens {
            let mut server = Server::new(
                qm.to_decode_model(engine),
                ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
            );
            let prompt: Vec<u16> = (0..16).map(|i| (i * 7 % 250) as u16).collect();
            server.run(vec![Request::greedy(0, prompt, ol)]);
            table.row(vec![
                label.into(),
                ol.to_string(),
                format!("{:.1}", server.metrics.tokens_per_s),
                format!("{:.2}", server.metrics.weight_bytes as f64 / 1e6),
            ]);
            j.insert(&ol.to_string(), server.metrics.tokens_per_s);
        }
        raw.insert(label, j);
    }
    raw.insert("model_bpw", report.effective_bpw);
    ctx.save("fig7", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 15 — qualitative generations at 1.0 / 0.8 / 0.55 bits.
// ---------------------------------------------------------------------------

pub fn table15(ctx: &Ctx) {
    let p = prepare(ctx, "l2", "s");
    let prompt_text = "the robin is";
    let mut table = Table::new(
        "Table 15 — qualitative continuations (prompt: 'the robin is')",
        &["Model", "Continuation"],
    );
    let mut raw = Json::obj();
    let gen = |dm: crate::nn::decode::DecodeModel| -> String {
        let mut server =
            Server::new(dm, ServerConfig { max_batch: 1, seed: ctx.seed, ..Default::default() });
        let reqs = vec![Request::new(0, crate::data::tokenize(prompt_text))
            .max_new(48)
            .temperature(0.8)
            .top_k(32)];
        server.run(reqs)[0].text.clone()
    };
    let teacher_dm = crate::nn::decode::dense_decode_model(&p.teacher);
    let text = gen(teacher_dm);
    table.row(vec!["FP teacher".into(), text.clone()]);
    raw.insert("fp", text);
    for bpw in [1.0, 0.8, 0.55] {
        let (qm, _, _) = nanoquant_run(ctx, &p, bpw);
        let text = gen(qm.to_decode_model(Engine::Packed));
        table.row(vec![format!("{bpw:.2}-bit NanoQuant"), text.clone()]);
        raw.insert(&format!("bpw{bpw}"), text);
    }
    ctx.save("table15", &table, raw);
}

// ---------------------------------------------------------------------------
// Streaming / online / cancellation workloads — the event-engine axes.
// No direct paper analogue: these measure what the offline batch API could
// not even express (externally observable TTFT, mid-flight arrival parity,
// page reclamation on cancel) on the real packed engine.
// ---------------------------------------------------------------------------

pub fn streaming(ctx: &Ctx) {
    let size = if ctx.quick { "xs" } else { "s" };
    let p = prepare(ctx, "l2", size);
    let (qm, _, _) = nanoquant_run(ctx, &p, 1.0);
    let mut table = Table::new(
        "Streaming serving workloads — event-driven engine on the packed kernels (token streaming, online arrival, cancellation)",
        &["Scenario", "Metric", "Value"],
    );
    let mut raw = Json::obj();

    // -- Token streaming: the first Token event lands strictly before the
    // request finishes, making TTFT externally observable.
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
    );
    let prompt: Vec<u16> = (0..48).map(|i| (i * 5 % 250) as u16).collect();
    engine.submit(Request::greedy(0, prompt, 16));
    let (mut first_token_step, mut finish_step) = (None::<usize>, 0usize);
    let mut ttft_s = 0.0f64;
    let mut step = 0usize;
    while !engine.is_idle() {
        for ev in engine.step() {
            match ev {
                Event::Token { .. } if first_token_step.is_none() => {
                    first_token_step = Some(step);
                }
                Event::Finished { response, .. } => {
                    finish_step = step;
                    ttft_s = response.ttft_s;
                }
                _ => {}
            }
        }
        step += 1;
    }
    let m = engine.snapshot();
    let first = first_token_step.expect("no token streamed");
    table.row(vec![
        "stream".into(),
        "first-token step / finish step".into(),
        format!("{first} / {finish_step}"),
    ]);
    table.row(vec!["stream".into(), "ttft (s)".into(), format!("{ttft_s:.4}")]);
    table.row(vec![
        "stream".into(),
        "decode throughput (tok/s)".into(),
        format!("{:.1}", m.tokens_per_s),
    ]);
    raw.insert(
        "stream",
        Json::obj()
            .set("first_token_step", first)
            .set("finish_step", finish_step)
            .set("ttft_s", ttft_s)
            .set("tok_s", m.tokens_per_s),
    );

    // -- Online arrival: a request submitted mid-flight must generate
    // exactly what it would have generated submitted up front.
    let pa: Vec<u16> = (0..12).map(|i| (i * 13 % 250) as u16).collect();
    let pb: Vec<u16> = (0..7).map(|i| (i * 17 + 2) as u16 % 250).collect();
    let mut offline = Server::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 2, seed: 0, ..Default::default() },
    );
    let want: Vec<Vec<u16>> = offline
        .run(vec![Request::greedy(0, pa.clone(), 8), Request::greedy(1, pb.clone(), 8)])
        .into_iter()
        .map(|r| r.tokens)
        .collect();
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 2, seed: 0, ..Default::default() },
    );
    engine.submit(Request::greedy(0, pa, 8));
    for _ in 0..3 {
        engine.step();
    }
    engine.submit(Request::greedy(1, pb, 8));
    let mut got: Vec<(u64, Vec<u16>)> = Vec::new();
    while !engine.is_idle() {
        for ev in engine.step() {
            if let Event::Finished { response, .. } = ev {
                got.push((response.id, response.tokens));
            }
        }
    }
    got.sort_by_key(|(id, _)| *id);
    let online_ok = got.len() == 2 && got[0].1 == want[0] && got[1].1 == want[1];
    assert!(online_ok, "mid-flight submission changed the output");
    table.row(vec![
        "online-arrival".into(),
        "mid-flight tokens == up-front tokens".into(),
        format!("{online_ok}"),
    ]);
    raw.insert("online_arrival_ok", online_ok);

    // -- Cancellation: cancel one of three page-hungry requests mid-decode;
    // its pages must come back and the deferred request must complete.
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 4, seed: 0, kv_pages: Some(4), ..Default::default() },
    );
    let total_pages = engine.pool().total_pages();
    for i in 0..3u64 {
        let prompt: Vec<u16> = (0..40).map(|j| ((i as usize * 7 + j) % 250) as u16).collect();
        engine.submit(Request::greedy(i, prompt, 8));
    }
    let mut deferred_seen = false;
    let mut cancelled_at: Option<usize> = None;
    let mut finished: Vec<(u64, usize, FinishReason)> = Vec::new();
    let mut step = 0usize;
    while !engine.is_idle() {
        let events = engine.step();
        for ev in &events {
            if matches!(ev, Event::Deferred { .. }) {
                deferred_seen = true;
            }
        }
        if cancelled_at.is_none()
            && events.iter().any(|e| matches!(e, Event::Token { id: 0, .. }))
        {
            engine.cancel(0);
            cancelled_at = Some(step);
        }
        for ev in events {
            if let Event::Finished { response, reason } = ev {
                finished.push((response.id, response.tokens.len(), reason));
            }
        }
        step += 1;
    }
    let pool_restored =
        engine.pool().in_use_pages() == 0 && engine.pool().unreserved_pages() == total_pages;
    let cancelled = finished.iter().any(|&(id, _, r)| id == 0 && r == FinishReason::Cancelled);
    let survivors_ok = finished
        .iter()
        .filter(|&&(id, _, _)| id != 0)
        .all(|&(_, n, r)| n == 8 && r == FinishReason::MaxNew);
    assert!(cancelled && survivors_ok && pool_restored, "cancellation workload failed");
    table.row(vec![
        "cancel".into(),
        "deferral observed / pages restored".into(),
        format!("{deferred_seen} / {pool_restored}"),
    ]);
    table.row(vec![
        "cancel".into(),
        "cancelled mid-decode at step".into(),
        format!("{}", cancelled_at.unwrap_or(0)),
    ]);
    table.row(vec![
        "cancel".into(),
        "survivors completed (tokens)".into(),
        "8 / 8".into(),
    ]);
    raw.insert(
        "cancel",
        Json::obj()
            .set("deferred_seen", deferred_seen)
            .set("pool_restored", pool_restored)
            .set("cancellations", engine.snapshot().cancellations),
    );
    ctx.save("streaming", &table, raw);
}
