//! Accuracy experiments: Table 2 (perplexity grid), Table 3 (zero-shot),
//! Fig. 1 (PPL vs effective BPW), Fig. 6 (Pareto frontier).

use super::zoo;
use super::Ctx;
use crate::data::{sample_sequences, CorpusKind};
use crate::eval::{perplexity, zero_shot_suite};
use crate::nn::model::ModelParams;
use crate::nn::LayerId;
use crate::quant::baselines::{
    arbllm::ArbLlmRc, billm::BiLlm, gptq::Gptq, hbllm::HbLlmCol, quantize_model_with,
    stbllm::StbLlm, Rtn, WeightQuantizer, Xnor,
};
use crate::quant::pipeline::{calibrate_preconditioners, quantize, PipelineConfig};
use crate::quant::{AdmmConfig, QuantModel, QuantReport};
use crate::util::json::Json;
use crate::util::tables::{fmt_ppl, Table};
use std::collections::BTreeMap;

/// Everything needed to quantize + evaluate one teacher.
pub struct Prepared {
    pub teacher: ModelParams,
    pub calib: Vec<Vec<u16>>,
    pub seq: usize,
    pub d_ins: BTreeMap<LayerId, Vec<f32>>,
    pub eval_toks: Vec<u16>,
    pub eval_windows: usize,
}

pub fn prepare(ctx: &Ctx, family: &str, size: &str) -> Prepared {
    let tokens = zoo::train_tokens();
    let teacher = zoo::teacher(&ctx.checkpoints, family, size, &tokens, true);
    let seq = 48usize;
    let n_calib = if ctx.quick { 8 } else { 24 };
    let mut rng = crate::util::rng::Rng::new(ctx.seed ^ 0xCA11B);
    let calib = sample_sequences(&tokens, seq + 1, n_calib, &mut rng);
    // Input sensitivities for the baselines (same calibration pass).
    let pcfg = pipeline_cfg(ctx, 1.0);
    let pre = calibrate_preconditioners(&teacher, &calib, seq, &pcfg);
    let d_ins = pre.into_iter().map(|(id, (_out, d_in))| (id, d_in)).collect();
    Prepared {
        teacher,
        calib,
        seq,
        d_ins,
        eval_toks: zoo::eval_tokens(CorpusKind::SynthText),
        eval_windows: if ctx.quick { 6 } else { 16 },
    }
}

/// Pipeline config scaled to the experiment budget.
pub fn pipeline_cfg(ctx: &Ctx, bpw: f64) -> PipelineConfig {
    if ctx.quick {
        PipelineConfig {
            bpw,
            t_pre: 6,
            t_post: 12,
            t_glob: 6,
            stats_seqs: 8,
            admm: AdmmConfig { iters: 10, ..Default::default() },
            seed: ctx.seed,
            ..Default::default()
        }
    } else {
        PipelineConfig {
            bpw,
            t_pre: 12,
            t_post: 32,
            t_glob: 16,
            stats_seqs: 16,
            admm: AdmmConfig { iters: 30, ..Default::default() },
            seed: ctx.seed,
            ..Default::default()
        }
    }
}

pub fn ppl_of(p: &Prepared, params: &ModelParams) -> f64 {
    perplexity(params, &p.eval_toks, p.seq, p.eval_windows)
}

/// Run NanoQuant at a BPW target and return (model, report, ppl).
pub fn nanoquant_run(ctx: &Ctx, p: &Prepared, bpw: f64) -> (QuantModel, QuantReport, f64) {
    let cfg = pipeline_cfg(ctx, bpw);
    let (qm, report) = quantize(&p.teacher, &p.calib, p.seq, &cfg);
    let ppl = ppl_of(p, &qm.params);
    (qm, report, ppl)
}

/// Run a baseline quantizer and return (ppl, achieved bpw, size bytes).
pub fn baseline_run(p: &Prepared, q: &dyn WeightQuantizer) -> (f64, f64, usize) {
    let res = quantize_model_with(q, &p.teacher, &p.d_ins);
    (ppl_of(p, &res.params), res.effective_bpw, res.effective_bytes)
}

/// The baseline set of Table 2 (name, total-bits label, quantizer).
pub fn binary_ptq_baselines() -> Vec<(&'static str, Box<dyn WeightQuantizer>)> {
    vec![
        ("RTN", Box::new(Rtn)),
        ("XNOR", Box::new(Xnor)),
        ("BiLLM", Box::new(BiLlm::default())),
        ("STBLLM (6:8)", Box::new(StbLlm::new(6, 8))),
        ("ARB-LLM_RC", Box::new(ArbLlmRc::default())),
        ("HBLLM_col", Box::new(HbLlmCol::default())),
        ("GPTQ (W2g64)", Box::new(Gptq::default())),
    ]
}

// ---------------------------------------------------------------------------
// Table 2 — WikiText-2-analogue perplexity across families and bitrates.
// ---------------------------------------------------------------------------

pub fn table2(ctx: &Ctx) {
    let mut table = Table::new(
        "Table 2 — perplexity (synthtext eval) of 1-bit and sub-1-bit PTQ",
        &["Method", "W Bits", "l2-s", "l3-s", "g3-s", "q3-s", "r1-s"],
    );
    let mut raw = Json::obj();
    let preps: Vec<(String, Prepared)> = zoo::FAMILIES
        .iter()
        .map(|f| (f.to_string(), prepare(ctx, f, "s")))
        .collect();

    // FP16 teacher row.
    let mut row = vec!["FP teacher".to_string(), "16.00".to_string()];
    let mut teacher_json = Json::obj();
    for (f, p) in &preps {
        let ppl = ppl_of(p, &p.teacher);
        teacher_json.insert(f, ppl);
        row.push(fmt_ppl(ppl));
    }
    table.row(row);
    raw.insert("fp16", teacher_json);

    // Binary PTQ baselines.
    for (name, q) in binary_ptq_baselines() {
        let mut row = vec![name.to_string(), String::new()];
        let mut j = Json::obj();
        let mut bpw_seen = 0.0;
        for (f, p) in &preps {
            let (ppl, bpw, _) = baseline_run(p, q.as_ref());
            j.insert(f, Json::obj().set("ppl", ppl).set("bpw", bpw));
            bpw_seen = bpw;
            row.push(fmt_ppl(ppl));
        }
        row[1] = format!("{bpw_seen:.2}");
        table.row(row);
        raw.insert(name, j);
    }

    // NanoQuant at 1.0 / 0.8 / 0.55 bits.
    for bpw in [1.0, 0.8, 0.55] {
        let mut row = vec![format!("NanoQuant"), format!("{bpw:.2}")];
        let mut j = Json::obj();
        for (f, p) in &preps {
            let (_, report, ppl) = nanoquant_run(ctx, p, bpw);
            j.insert(
                f,
                Json::obj()
                    .set("ppl", ppl)
                    .set("bpw", report.effective_bpw)
                    .set("bytes", report.effective_bytes)
                    .set("wall_s", report.wall_seconds),
            );
            row.push(fmt_ppl(ppl));
        }
        table.row(row);
        raw.insert(&format!("nanoquant@{bpw}"), j);
    }
    ctx.save("table2", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 3 — zero-shot accuracy.
// ---------------------------------------------------------------------------

pub fn table3(ctx: &Ctx) {
    let mut table = Table::new(
        "Table 3 — zero-shot accuracy (synthetic suite)",
        &[
            "Model", "Bits", "Method", "ARC-e*", "ARC-c*", "BoolQ*", "Hella*", "Wino*", "PIQA*",
            "Avg.",
        ],
    );
    let mut raw = Json::obj();
    let items = if ctx.quick { 20 } else { 40 };
    for family in ["l3", "q3"] {
        let p = prepare(ctx, family, "s");
        let mut eval_model = |name: &str, bits: f64, params: &ModelParams, raw: &mut Json| {
            let (per_task, avg) = zero_shot_suite(params, items, ctx.seed);
            let mut row = vec![
                format!("{family}-s"),
                format!("{bits:.2}"),
                name.to_string(),
            ];
            let mut j = Json::obj();
            for (task, acc) in &per_task {
                row.push(format!("{acc:.2}"));
                j.insert(task, *acc);
            }
            row.push(format!("{avg:.2}"));
            j.insert("avg", avg);
            table.row(row);
            raw.insert(&format!("{family}/{name}"), j);
        };
        eval_model("BF16", 16.0, &p.teacher.clone(), &mut raw);
        for (name, q) in binary_ptq_baselines() {
            if name == "RTN" || name == "XNOR" {
                continue; // catastrophic rows add nothing to Table 3
            }
            let res = quantize_model_with(q.as_ref(), &p.teacher, &p.d_ins);
            eval_model(name, res.effective_bpw, &res.params.clone(), &mut raw);
        }
        let (qm, report, _) = nanoquant_run(ctx, &p, 1.0);
        eval_model("NanoQuant", report.effective_bpw, &qm.params.clone(), &mut raw);
    }
    ctx.save("table3", &table, raw);
}

// ---------------------------------------------------------------------------
// Fig. 1 — PPL vs effective storage; Fig. 6 — Pareto frontier.
// ---------------------------------------------------------------------------

pub fn fig1(ctx: &Ctx) {
    // Derived from the table2 measurements (re-run if absent).
    let path = format!("{}/table2.json", ctx.results);
    if !std::path::Path::new(&path).exists() {
        eprintln!("[fig1] table2 results missing; running table2 first");
        table2(ctx);
    }
    let raw = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let mut table = Table::new(
        "Fig. 1 — PPL vs effective BPW (series: method, x: BPW, y: ppl, per family)",
        &["Method", "Family", "BPW", "PPL"],
    );
    if let Json::Obj(methods) = &raw {
        for (method, fams) in methods {
            if method == "fp16" {
                continue;
            }
            if let Json::Obj(fmap) = fams {
                for (fam, v) in fmap {
                    let (Some(ppl), Some(bpw)) = (
                        v.get("ppl").and_then(|x| x.as_f64()),
                        v.get("bpw").and_then(|x| x.as_f64()),
                    ) else {
                        continue;
                    };
                    table.row(vec![
                        method.clone(),
                        fam.clone(),
                        format!("{bpw:.2}"),
                        fmt_ppl(ppl),
                    ]);
                }
            }
        }
    }
    ctx.save("fig1", &table, raw);
}

pub fn fig6(ctx: &Ctx) {
    let mut table = Table::new(
        "Fig. 6 — Pareto frontier, q3 family (x: model MB, y: ppl)",
        &["Method", "Model", "Size (MB)", "BPW", "PPL"],
    );
    let mut raw = Json::obj();
    let sizes = if ctx.quick { vec!["xs", "s"] } else { vec!["xs", "s", "m"] };
    for size in sizes {
        let p = prepare(ctx, "q3", size);
        // FP16 point.
        let fp_bytes: usize = crate::nn::param_count(&p.teacher.cfg) * 2;
        table.row(vec![
            "BF16".into(),
            format!("q3-{size}"),
            format!("{:.2}", fp_bytes as f64 / 1e6),
            "16.00".into(),
            fmt_ppl(ppl_of(&p, &p.teacher)),
        ]);
        for (name, q) in binary_ptq_baselines() {
            if name == "RTN" || name == "XNOR" {
                continue;
            }
            let (ppl, bpw, bytes) = baseline_run(&p, q.as_ref());
            table.row(vec![
                name.to_string(),
                format!("q3-{size}"),
                format!("{:.2}", bytes as f64 / 1e6),
                format!("{bpw:.2}"),
                fmt_ppl(ppl),
            ]);
            raw.insert(
                &format!("{name}/q3-{size}"),
                Json::obj().set("ppl", ppl).set("bytes", bytes).set("bpw", bpw),
            );
        }
        for bpw in [1.0, 0.8, 0.55] {
            let (_, report, ppl) = nanoquant_run(ctx, &p, bpw);
            table.row(vec![
                format!("NanoQuant@{bpw}"),
                format!("q3-{size}"),
                format!("{:.2}", report.effective_bytes as f64 / 1e6),
                format!("{:.2}", report.effective_bpw),
                fmt_ppl(ppl),
            ]);
            raw.insert(
                &format!("nanoquant@{bpw}/q3-{size}"),
                Json::obj()
                    .set("ppl", ppl)
                    .set("bytes", report.effective_bytes)
                    .set("bpw", report.effective_bpw),
            );
        }
    }
    ctx.save("fig6", &table, raw);
}
