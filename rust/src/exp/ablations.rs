//! Ablations: Table 5 (initialization), Table 6 (component efficacy),
//! Table 9 (data budgets), Table 10 (calibration mixture), Fig. 8 (latent
//! dynamics), Fig. 9 (ADMM iterations / penalty schedules).

use super::accuracy::{pipeline_cfg, ppl_of, prepare};
use super::{zoo, Ctx};
use crate::data::{gen_corpus, sample_sequences, tokenize, CorpusKind};
use crate::eval::{perplexity, zero_shot_suite};
use crate::quant::pipeline::quantize;
use crate::quant::recon::tune_scales_global;
use crate::quant::{lb_admm, AdmmConfig, InitMethod, RhoSchedule};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tables::{fmt_ppl, Table};

// ---------------------------------------------------------------------------
// Table 5 — initialization strategy (on the r1 family at 0.8 bits).
// ---------------------------------------------------------------------------

pub fn table5(ctx: &Ctx) {
    let p = prepare(ctx, "r1", "s");
    let mut table = Table::new(
        "Table 5 — initialization ablation (r1-s @ 0.8 bits)",
        &["Initialization Method", "PPL", "Zero-shot"],
    );
    let mut raw = Json::obj();
    let items = if ctx.quick { 15 } else { 30 };
    for init in [InitMethod::DualSvid, InitMethod::DbfAdmm, InitMethod::LbAdmm] {
        let mut cfg = pipeline_cfg(ctx, 0.8);
        cfg.init = init;
        let (qm, _) = quantize(&p.teacher, &p.calib, p.seq, &cfg);
        let ppl = ppl_of(&p, &qm.params);
        let (_, zs) = zero_shot_suite(&qm.params, items, ctx.seed);
        table.row(vec![init.name().into(), fmt_ppl(ppl), format!("{zs:.2}")]);
        raw.insert(init.name(), Json::obj().set("ppl", ppl).set("zs", zs));
    }
    ctx.save("table5", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 6 — component efficacy (q3-s @ 1 bit).
// ---------------------------------------------------------------------------

pub fn table6(ctx: &Ctx) {
    let p = prepare(ctx, "q3", "s");
    let mut table = Table::new(
        "Table 6 — component efficacy (q3-s @ 1 bit)",
        &["Init", "Error Mitig.", "Fact. Refine", "Model Recon.", "PPL", "Zero-shot"],
    );
    let mut raw = Json::obj();
    let items = if ctx.quick { 15 } else { 30 };
    // (init enabled?, mitigation, refinement, reconstruction)
    let rows = [
        (false, false, false, false),
        (true, true, false, false),
        (true, false, true, false),
        (true, true, true, false),
        (true, true, true, true),
    ];
    for (init, mitig, refine, recon) in rows {
        let mut cfg = pipeline_cfg(ctx, 1.0);
        cfg.init = if init { InitMethod::LbAdmm } else { InitMethod::Random };
        cfg.enable_mitigation = mitig;
        cfg.enable_refine = refine;
        cfg.enable_recon = recon;
        let (qm, _) = quantize(&p.teacher, &p.calib, p.seq, &cfg);
        let ppl = ppl_of(&p, &qm.params);
        let (_, zs) = zero_shot_suite(&qm.params, items, ctx.seed);
        let mark = |b: bool| if b { "v" } else { "x" };
        table.row(vec![
            mark(init).into(),
            mark(mitig).into(),
            mark(refine).into(),
            mark(recon).into(),
            fmt_ppl(ppl),
            format!("{zs:.2}"),
        ]);
        raw.insert(
            &format!("init={init},mitig={mitig},refine={refine},recon={recon}"),
            Json::obj().set("ppl", ppl).set("zs", zs),
        );
    }
    ctx.save("table6", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 9 — data budgets for block vs model reconstruction (App. D.1).
// ---------------------------------------------------------------------------

pub fn table9(ctx: &Ctx) {
    let tokens = zoo::train_tokens();
    let teacher = zoo::teacher(&ctx.checkpoints, "l2", "s", &tokens, true);
    let eval_toks = zoo::eval_tokens(CorpusKind::SynthText);
    let seq = 48usize;
    let windows = if ctx.quick { 6 } else { 16 };
    let block_budgets = if ctx.quick { vec![8, 16] } else { vec![8, 16, 32] };
    let model_budgets = if ctx.quick { vec![8, 16] } else { vec![8, 16, 32] };

    let headers: Vec<String> = std::iter::once("Block \\ Model samples".to_string())
        .chain(model_budgets.iter().map(|m| m.to_string()))
        .collect();
    let mut table = Table::new(
        "Table 9 — calibration budgets: block recon samples x model recon samples (PPL, l2-s @ 1 bit)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut raw = Json::obj();
    for &nb in &block_budgets {
        let mut rng = Rng::new(ctx.seed ^ nb as u64);
        let calib = sample_sequences(&tokens, seq + 1, nb, &mut rng);
        // Block phase without the global phase.
        let mut cfg = pipeline_cfg(ctx, 1.0);
        cfg.enable_recon = false;
        let (qm_base, _) = quantize(&teacher, &calib, seq, &cfg);
        let mut row = vec![nb.to_string()];
        for &nm in &model_budgets {
            // Clone the block-reconstructed model, run Phase 3 with its own
            // budget of fresh sequences.
            let mut qm = crate::quant::QuantModel {
                params: qm_base.params.clone(),
                layers: qm_base.layers.clone(),
            };
            let mut rng2 = Rng::new(ctx.seed ^ 0xF00D ^ nm as u64);
            let recon_calib = sample_sequences(&tokens, seq + 1, nm, &mut rng2);
            tune_scales_global(
                &mut qm, &teacher, &recon_calib, cfg.t_glob, cfg.batch_seqs, seq,
                cfg.lr_glob, cfg.kl_temperature, &mut rng2, None,
            )
            .expect("watchdog off");
            let ppl = perplexity(&qm.params, &eval_toks, seq, windows);
            row.push(fmt_ppl(ppl));
            raw.insert(&format!("block{nb}_model{nm}"), ppl);
        }
        table.row(row);
    }
    ctx.save("table9", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 10 — calibration dataset composition (App. D.2).
// ---------------------------------------------------------------------------

pub fn table10(ctx: &Ctx) {
    let tokens_st = zoo::train_tokens();
    let tokens_wm = tokenize(&gen_corpus(CorpusKind::WebMix, 1_000_000, 4242));
    let teacher = zoo::teacher(&ctx.checkpoints, "l2", "s", &tokens_st, true);
    let eval_st = zoo::eval_tokens(CorpusKind::SynthText);
    let eval_wm = zoo::eval_tokens(CorpusKind::WebMix);
    let seq = 48usize;
    let windows = if ctx.quick { 6 } else { 16 };
    let total = if ctx.quick { 8 } else { 24 };

    let mut table = Table::new(
        "Table 10 — calibration mixture (l2-s @ 1 bit); WM=webmix(C4*), ST=synthtext(WT2*)",
        &["WM", "ST", "ST PPL", "WM PPL", "Zero-shot"],
    );
    let mut raw = Json::obj();
    let items = if ctx.quick { 15 } else { 30 };
    let fractions = [(0, 4), (1, 3), (2, 2), (3, 1), (4, 0)];
    for (wm_q, st_q) in fractions {
        let n_wm = total * wm_q / 4;
        let n_st = total * st_q / 4;
        let mut rng = Rng::new(ctx.seed ^ (wm_q as u64) << 4);
        let mut calib = if n_st > 0 {
            sample_sequences(&tokens_st, seq + 1, n_st, &mut rng)
        } else {
            vec![]
        };
        if n_wm > 0 {
            calib.extend(sample_sequences(&tokens_wm, seq + 1, n_wm, &mut rng));
        }
        let cfg = pipeline_cfg(ctx, 1.0);
        let (qm, _) = quantize(&teacher, &calib, seq, &cfg);
        let ppl_st = perplexity(&qm.params, &eval_st, seq, windows);
        let ppl_wm = perplexity(&qm.params, &eval_wm, seq, windows);
        let (_, zs) = zero_shot_suite(&qm.params, items, ctx.seed);
        table.row(vec![
            n_wm.to_string(),
            n_st.to_string(),
            fmt_ppl(ppl_st),
            fmt_ppl(ppl_wm),
            format!("{zs:.2}"),
        ]);
        raw.insert(
            &format!("wm{n_wm}_st{n_st}"),
            Json::obj().set("st_ppl", ppl_st).set("wm_ppl", ppl_wm).set("zs", zs),
        );
    }
    ctx.save("table10", &table, raw);
}

// ---------------------------------------------------------------------------
// Fig. 8 — latent dynamics during STE refinement (block 0).
// ---------------------------------------------------------------------------

pub fn fig8(ctx: &Ctx) {
    let p = prepare(ctx, "l2", "s");
    let cfg = pipeline_cfg(ctx, 1.0);
    let (_, report) = quantize(&p.teacher, &p.calib, p.seq, &cfg);
    let mut table = Table::new(
        "Fig. 8 — latent dynamics, block 0: sign-flip ratio and |delta| by initial magnitude",
        &[
            "Layer",
            "Flip %",
            "flips@|u0|<q25 %",
            "flips@|u0|>q75 %",
            "mean |delta| low-mag",
            "mean |delta| high-mag",
        ],
    );
    let mut raw = Json::obj();
    let block0 = report.ste.first().expect("refinement ran");
    for layer in &block0.layers {
        // Quartiles of initial magnitude.
        let mut mags: Vec<f32> = layer.samples.iter().map(|s| s.0).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if mags.is_empty() {
            continue;
        }
        let q25 = mags[mags.len() / 4];
        let q75 = mags[(3 * mags.len()) / 4];
        let low: Vec<_> = layer.samples.iter().filter(|s| s.0 < q25).collect();
        let high: Vec<_> = layer.samples.iter().filter(|s| s.0 > q75).collect();
        let flip_rate = |xs: &[&(f32, f32, bool)]| {
            100.0 * xs.iter().filter(|s| s.2).count() as f64 / xs.len().max(1) as f64
        };
        let mean_delta = |xs: &[&(f32, f32, bool)]| {
            xs.iter().map(|s| s.1 as f64).sum::<f64>() / xs.len().max(1) as f64
        };
        table.row(vec![
            layer.id.to_string(),
            format!("{:.2}", 100.0 * layer.flip_ratio),
            format!("{:.2}", flip_rate(&low)),
            format!("{:.2}", flip_rate(&high)),
            format!("{:.4}", mean_delta(&low)),
            format!("{:.4}", mean_delta(&high)),
        ]);
        raw.insert(
            &layer.id.to_string(),
            Json::obj()
                .set("flip_ratio", layer.flip_ratio)
                .set("flip_low_mag", flip_rate(&low))
                .set("flip_high_mag", flip_rate(&high)),
        );
    }
    ctx.save("fig8", &table, raw);
}

// ---------------------------------------------------------------------------
// Fig. 9 — ADMM ablations: outer iterations and penalty schedules.
// ---------------------------------------------------------------------------

pub fn fig9(ctx: &Ctx) {
    // Block-0 q_proj of the l2-m teacher (the paper uses Gemma block 0).
    let tokens = zoo::train_tokens();
    let teacher = zoo::teacher(&ctx.checkpoints, "l2", "m", &tokens, true);
    let w = teacher.blocks[0].wq.clone();
    let r = crate::quant::rank_for_bpw(w.rows(), w.cols(), 1.0);

    let mut table = Table::new(
        "Fig. 9 — ADMM ablations on l2-m block-0 q_proj (final binarized recon error)",
        &["Variant", "Iters", "Schedule", "Final err", "Err @25%", "Err @50%"],
    );
    let mut raw = Json::obj();

    // (a) outer-iteration sweep.
    for iters in [5usize, 10, 20, 40] {
        let cfg = AdmmConfig { iters, trace: true, seed: ctx.seed, ..Default::default() };
        let res = lb_admm(&w, r, &cfg);
        let errs = &res.trace.recon_err;
        let at = |f: f64| errs[((errs.len() - 1) as f64 * f) as usize];
        table.row(vec![
            "iterations".into(),
            iters.to_string(),
            "linear".into(),
            format!("{:.4}", errs.last().unwrap()),
            format!("{:.4}", at(0.25)),
            format!("{:.4}", at(0.5)),
        ]);
        raw.insert(
            &format!("iters{iters}"),
            Json::Arr(errs.iter().map(|&e| Json::Num(e)).collect()),
        );
    }

    // (b) penalty schedules at fixed iterations.
    for sched in [RhoSchedule::Constant, RhoSchedule::Linear, RhoSchedule::Exponential] {
        let cfg = AdmmConfig {
            iters: 30,
            schedule: sched,
            trace: true,
            seed: ctx.seed,
            ..Default::default()
        };
        let res = lb_admm(&w, r, &cfg);
        let errs = &res.trace.recon_err;
        let at = |f: f64| errs[((errs.len() - 1) as f64 * f) as usize];
        table.row(vec![
            "schedule".into(),
            "30".into(),
            format!("{sched:?}"),
            format!("{:.4}", errs.last().unwrap()),
            format!("{:.4}", at(0.25)),
            format!("{:.4}", at(0.5)),
        ]);
        raw.insert(
            &format!("sched_{sched:?}"),
            Json::Arr(errs.iter().map(|&e| Json::Num(e)).collect()),
        );
    }
    ctx.save("fig9", &table, raw);
}
