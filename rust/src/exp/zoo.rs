//! Teacher model zoo: the pretrained-checkpoint substitute.
//!
//! Teachers are trained once on the synthetic corpus and cached under
//! `checkpoints/`; every experiment loads from the cache so results are
//! reproducible and experiments are independently runnable.

use crate::data::{gen_corpus, tokenize, CorpusKind};
use crate::nn::checkpoint::{load_model, save_model};
use crate::nn::model::ModelParams;
use crate::nn::trainer::train;
use crate::nn::{family_config, param_count};
use crate::util::rng::Rng;

/// Models used across the experiment suite (family axis of Table 2 + the
/// size axes of Fig. 6 / Table 12).
pub const ZOO: &[(&str, &str)] = &[
    ("l2", "xs"),
    ("l2", "s"),
    ("l2", "m"),
    ("l3", "s"),
    ("g3", "s"),
    ("q3", "xs"),
    ("q3", "s"),
    ("q3", "m"),
    ("r1", "s"),
];

/// Families evaluated in the Table 2 / Table 3 grids.
pub const FAMILIES: &[&str] = &["l2", "l3", "g3", "q3", "r1"];

pub fn ckpt_path(dir: &str, family: &str, size: &str) -> String {
    format!("{dir}/{family}-{size}.bin")
}

/// Training budget per size (Adam steps).
fn steps_for(size: &str) -> usize {
    match size {
        "xs" => 300,
        "s" => 400,
        _ => 400,
    }
}

/// Shared training corpus (SynthText; WebMix is used by the D.2 ablation).
pub fn train_tokens() -> Vec<u16> {
    tokenize(&gen_corpus(CorpusKind::SynthText, 1_500_000, 1234))
}

/// Held-out eval stream (disjoint seed).
pub fn eval_tokens(kind: CorpusKind) -> Vec<u16> {
    tokenize(&gen_corpus(kind, 200_000, 777))
}

/// Load a cached teacher or train and cache it.
pub fn teacher(dir: &str, family: &str, size: &str, tokens: &[u16], verbose: bool) -> ModelParams {
    let path = ckpt_path(dir, family, size);
    if std::path::Path::new(&path).exists() {
        if let Ok(params) = load_model(&path) {
            return params;
        }
    }
    let cfg = family_config(family, size);
    if verbose {
        eprintln!(
            "[zoo] training {family}-{size} ({} params, {} steps)…",
            param_count(&cfg),
            steps_for(size)
        );
    }
    let mut rng = Rng::new(0x2EE7 ^ fxhash(family) ^ fxhash(size));
    let mut params = ModelParams::init(&cfg, &mut rng);
    train(&mut params, tokens, steps_for(size), 6, 48, 3e-3, 99, verbose);
    std::fs::create_dir_all(dir).ok();
    save_model(&path, &params).expect("save checkpoint");
    params
}

/// Train every zoo model (idempotent).
pub fn build_zoo(dir: &str, verbose: bool) {
    let tokens = train_tokens();
    for (family, size) in ZOO {
        let t0 = std::time::Instant::now();
        let _ = teacher(dir, family, size, &tokens, verbose);
        if verbose {
            eprintln!("[zoo] {family}-{size} ready ({:.1}s)", t0.elapsed().as_secs_f64());
        }
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_cache_roundtrip() {
        let dir = "/tmp/nanoquant_zoo_test";
        std::fs::remove_dir_all(dir).ok();
        let tokens: Vec<u16> = train_tokens()[..100_000].to_vec();
        // Train a throwaway xs teacher with a tiny budget by calling teacher
        // directly (steps_for(xs)=300 is fine in release tests).
        let a = teacher(dir, "l2", "xs", &tokens, false);
        assert!(std::path::Path::new(&ckpt_path(dir, "l2", "xs")).exists());
        let b = teacher(dir, "l2", "xs", &tokens, false);
        assert_eq!(a.embed, b.embed, "second call must load the cache");
        std::fs::remove_dir_all(dir).ok();
    }
}
