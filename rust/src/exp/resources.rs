//! Resource-efficiency experiments: Table 4 (compression cost), Table 7
//! (PTQ vs low-rank binary QAT), Table 8 (vs vector quantization).

use super::accuracy::{baseline_run, nanoquant_run, pipeline_cfg, ppl_of, prepare};
use super::{zoo, Ctx};
use crate::eval::zero_shot_suite;
use crate::quant::baselines::qat::{qat_train, QatConfig};
use crate::quant::baselines::vq::KmeansVq;
use crate::quant::baselines::{quantize_model_with, WeightQuantizer};
use crate::quant::pipeline::quantize;
use crate::quant::InitMethod;
use crate::util::json::Json;
use crate::util::tables::{fmt_ppl, Table};
use crate::util::timer::time_once;

// ---------------------------------------------------------------------------
// Table 4 — compression & resource efficiency on the l2-s teacher.
// ---------------------------------------------------------------------------

pub fn table4(ctx: &Ctx) {
    let p = prepare(ctx, "l2", "s");
    let mut table = Table::new(
        "Table 4 — compression cost vs quality (l2-s teacher)",
        &["Method", "Scheme", "Bits", "Size (MB)", "Data (tokens)", "Wall (s)", "PPL"],
    );
    let mut raw = Json::obj();
    let calib_tokens = p.calib.len() * p.seq;

    // Full precision reference.
    let fp_bytes = crate::nn::param_count(&p.teacher.cfg) * 2;
    table.row(vec![
        "Full-Precision".into(),
        "-".into(),
        "16.00".into(),
        format!("{:.2}", fp_bytes as f64 / 1e6),
        "-".into(),
        "-".into(),
        fmt_ppl(ppl_of(&p, &p.teacher)),
    ]);

    // PTQ baselines (calibration-only data, measured wall-clock).
    for (name, q) in super::accuracy::binary_ptq_baselines() {
        if name == "RTN" || name == "XNOR" {
            continue;
        }
        let ((ppl, bpw, bytes), secs) = time_once(|| baseline_run(&p, q.as_ref()));
        table.row(vec![
            name.to_string(),
            "PTQ".into(),
            format!("{bpw:.2}"),
            format!("{:.2}", bytes as f64 / 1e6),
            format!("{calib_tokens}"),
            format!("{secs:.1}"),
            fmt_ppl(ppl),
        ]);
        raw.insert(name, Json::obj().set("ppl", ppl).set("bpw", bpw).set("wall_s", secs));
    }

    // QAT baselines: far more data, far more compute (the paper's gap).
    let tokens = zoo::train_tokens();
    let qat_steps = if ctx.quick { 60 } else { 300 };
    for (name, init) in
        [("LittleBit (QAT)", InitMethod::DualSvid), ("DBF (QAT)", InitMethod::DbfAdmm)]
    {
        let qcfg = QatConfig {
            bpw: 1.0,
            init,
            steps: qat_steps,
            batch: 4,
            seq: p.seq,
            seed: ctx.seed,
            ..Default::default()
        };
        let (qm, report) = qat_train(&p.teacher, &tokens, &qcfg);
        let ppl = ppl_of(&p, &qm.params);
        table.row(vec![
            name.into(),
            "QAT".into(),
            format!("{:.2}", qm.effective_bpw()),
            format!("{:.2}", qm.effective_bytes() as f64 / 1e6),
            format!("{}", report.tokens_seen),
            format!("{:.1}", report.wall_seconds),
            fmt_ppl(ppl),
        ]);
        raw.insert(
            name,
            Json::obj()
                .set("ppl", ppl)
                .set("tokens", report.tokens_seen)
                .set("wall_s", report.wall_seconds),
        );
    }

    // NanoQuant: default calibration budget + a 2x-data variant.
    for (label, extra) in [("NanoQuant", 1usize), ("NanoQuant (2x data)", 2)] {
        let mut rng = crate::util::rng::Rng::new(ctx.seed ^ 0xDA7A);
        let calib =
            crate::data::sample_sequences(&tokens, p.seq + 1, p.calib.len() * extra, &mut rng);
        let cfg = pipeline_cfg(ctx, 1.0);
        let (qm, report) = quantize(&p.teacher, &calib, p.seq, &cfg);
        let ppl = ppl_of(&p, &qm.params);
        table.row(vec![
            label.into(),
            "PTQ".into(),
            format!("{:.2}", report.effective_bpw),
            format!("{:.2}", report.effective_bytes as f64 / 1e6),
            format!("{}", report.calib_tokens),
            format!("{:.1}", report.wall_seconds),
            fmt_ppl(ppl),
        ]);
        raw.insert(
            label,
            Json::obj()
                .set("ppl", ppl)
                .set("tokens", report.calib_tokens)
                .set("wall_s", report.wall_seconds),
        );
    }
    ctx.save("table4", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 7 — vs low-rank binary QAT at matched 1 bit.
// ---------------------------------------------------------------------------

pub fn table7(ctx: &Ctx) {
    let mut table = Table::new(
        "Table 7 — NanoQuant (PTQ) vs low-rank binary QAT at 1 bit",
        &["Model", "Method", "Data (tokens)", "Wall (s)", "PPL", "Zero-shot"],
    );
    let mut raw = Json::obj();
    let tokens = zoo::train_tokens();
    let items = if ctx.quick { 15 } else { 30 };
    let qat_steps = if ctx.quick { 60 } else { 300 };
    for family in ["q3", "l2"] {
        let p = prepare(ctx, family, "s");
        for (name, init) in
            [("LittleBit", InitMethod::DualSvid), ("DBF", InitMethod::DbfAdmm)]
        {
            let qcfg = QatConfig {
                bpw: 1.0,
                init,
                steps: qat_steps,
                batch: 4,
                seq: p.seq,
                seed: ctx.seed,
                ..Default::default()
            };
            let (qm, report) = qat_train(&p.teacher, &tokens, &qcfg);
            let ppl = ppl_of(&p, &qm.params);
            let (_, zs) = zero_shot_suite(&qm.params, items, ctx.seed);
            table.row(vec![
                format!("{family}-s"),
                name.into(),
                format!("{}", report.tokens_seen),
                format!("{:.1}", report.wall_seconds),
                fmt_ppl(ppl),
                format!("{zs:.2}"),
            ]);
            raw.insert(
                &format!("{family}/{name}"),
                Json::obj().set("ppl", ppl).set("zs", zs).set("tokens", report.tokens_seen),
            );
        }
        let (qm, report, ppl) = nanoquant_run(ctx, &p, 1.0);
        let (_, zs) = zero_shot_suite(&qm.params, items, ctx.seed);
        table.row(vec![
            format!("{family}-s"),
            "NanoQuant".into(),
            format!("{}", report.calib_tokens),
            format!("{:.1}", report.wall_seconds),
            fmt_ppl(ppl),
            format!("{zs:.2}"),
        ]);
        raw.insert(
            &format!("{family}/nanoquant"),
            Json::obj().set("ppl", ppl).set("zs", zs).set("tokens", report.calib_tokens),
        );
    }
    ctx.save("table7", &table, raw);
}

// ---------------------------------------------------------------------------
// Table 8 — vs vector quantization at 2 / 1.5 / 1 bits.
// ---------------------------------------------------------------------------

pub fn table8(ctx: &Ctx) {
    let p = prepare(ctx, "l2", "s");
    let mut table = Table::new(
        "Table 8 — NanoQuant vs vector quantization (l2-s)",
        &["Target", "Method", "Bits", "Size (MB)", "PPL", "Zero-shot"],
    );
    let mut raw = Json::obj();
    let items = if ctx.quick { 15 } else { 30 };

    let mut vq_row = |target: &str, name: &str, q: &dyn WeightQuantizer, raw: &mut Json| {
        let res = quantize_model_with(q, &p.teacher, &p.d_ins);
        let ppl = ppl_of(&p, &res.params);
        let (_, zs) = zero_shot_suite(&res.params, items, ctx.seed);
        table.row(vec![
            target.into(),
            name.into(),
            format!("{:.2}", res.effective_bpw),
            format!("{:.2}", res.effective_bytes as f64 / 1e6),
            fmt_ppl(ppl),
            format!("{zs:.2}"),
        ]);
        raw.insert(name, Json::obj().set("ppl", ppl).set("zs", zs).set("bpw", res.effective_bpw));
    };
    vq_row("2-bit", "QTIP-like", &KmeansVq::qtip_like(ctx.seed), &mut raw);
    vq_row("2-bit", "AQLM-like", &KmeansVq::aqlm_like(ctx.seed), &mut raw);
    vq_row("2-bit", "AQLM+PV-like", &KmeansVq::aqlm_pv_like(ctx.seed), &mut raw);

    for (target, bpw) in [("2-bit", 2.0), ("1.5-bit", 1.5), ("1-bit", 1.0)] {
        let (qm, report, ppl) = nanoquant_run(ctx, &p, bpw);
        let (_, zs) = zero_shot_suite(&qm.params, items, ctx.seed);
        table.row(vec![
            target.into(),
            format!("NanoQuant@{bpw}"),
            format!("{:.2}", report.effective_bpw),
            format!("{:.2}", report.effective_bytes as f64 / 1e6),
            fmt_ppl(ppl),
            format!("{zs:.2}"),
        ]);
        raw.insert(
            &format!("nanoquant@{bpw}"),
            Json::obj().set("ppl", ppl).set("zs", zs).set("bpw", report.effective_bpw),
        );
    }
    ctx.save("table8", &table, raw);
}
