//! # NanoQuant
//!
//! A production-oriented reproduction of *"NanoQuant: Efficient Sub-1-Bit
//! Quantization of Large Language Models"* (ICML 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: the full post-training
//!   quantization pipeline (robust Hessian preconditioning, LB-ADMM latent
//!   binary factorization, magnitude balancing, STE block refinement,
//!   scale-only KL model reconstruction), every baseline quantizer the paper
//!   compares against, an event-driven serving runtime (online submission,
//!   token streaming, cancellation, continuous batching over a paged
//!   KV-cache pool — see [`serve::Engine`]), and the experiment harness that
//!   regenerates every table and figure of the paper.
//! - **Layer 2 (python/compile/model.py)** — the JAX transformer graphs,
//!   AOT-lowered once to HLO text and executed from Rust via PJRT.
//! - **Layer 1 (python/compile/kernels/)** — Pallas packed binary low-rank
//!   GEMV/GEMM kernels, lowered into the L2 graphs.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for
//! paper-vs-measured results and perf tuning notes (both at the repository
//! root).

// Clippy house-style allows live in Cargo.toml `[lints.clippy]` so they
// cover every target (bin, tests, benches, out-of-tree examples), not just
// this library crate.

pub mod data;
pub mod eval;
pub mod exp;
pub mod linalg;
pub mod model;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
