//! Integration + property tests for the serving coordinator with a *real*
//! quantized model (not just the dense tiny model of the unit tests).

use nanoquant::nn::family_config;
use nanoquant::nn::model::{LayerKind, ModelParams};
use nanoquant::nn::LayerId;
use nanoquant::quant::{rank_for_bpw, Engine, LatentFactors, QuantModel};
use nanoquant::serve::{Engine as ServeEngine, Event, FinishReason, Request, Server, ServerConfig};
use nanoquant::tensor::Tensor;
use nanoquant::util::quickcheck::check;
use nanoquant::util::rng::Rng;

fn quant_model() -> QuantModel {
    let cfg = family_config("l3", "xs"); // GQA path
    let mut rng = Rng::new(0);
    let params = ModelParams::init(&cfg, &mut rng);
    let mut qm = QuantModel::from_teacher(&params);
    for bi in 0..cfg.n_layers {
        for kind in LayerKind::ALL {
            let w = params.blocks[bi].linear(kind);
            let (n, m) = (w.rows(), w.cols());
            let r = rank_for_bpw(n, m, 2.0).min(n).min(m);
            qm.set_layer(
                LayerId { block: bi, kind },
                LatentFactors {
                    u: Tensor::randn(&[n, r], 1.0, &mut rng),
                    v: Tensor::randn(&[m, r], 1.0, &mut rng),
                    s1: (0..n).map(|_| rng.uniform_in(0.01, 0.03)).collect(),
                    s2: (0..m).map(|_| rng.uniform_in(0.5, 1.5)).collect(),
                },
            );
        }
        qm.freeze_block(bi);
    }
    qm
}

#[test]
fn packed_and_naive_engines_generate_identical_greedy_output() {
    let qm = quant_model();
    let prompt: Vec<u16> = vec![5, 10, 15, 20];
    let mut out = Vec::new();
    for engine in [Engine::Packed, Engine::NaiveUnpack, Engine::Dense] {
        let mut server = Server::new(
            qm.to_decode_model(engine),
            ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
        );
        let resp = server.run(vec![Request::greedy(0, prompt.clone(), 12)]);
        out.push(resp[0].tokens.clone());
    }
    assert_eq!(out[0], out[1], "packed vs naive-unpack");
    assert_eq!(out[0], out[2], "packed vs dense(materialized)");
}

#[test]
fn chunked_prefill_is_byte_identical_on_the_packed_engine() {
    // The acceptance bar for chunked prefill, on the real packed kernels
    // (multi-token packed GEMM + chunk-wide byte LUT): any chunk size must
    // generate exactly the tokens of the one-token-per-tick path, while
    // spending ceil(prompt / chunk) prefill ticks.
    let qm = quant_model();
    let prompt: Vec<u16> = (0..33).map(|i| ((i * 11 + 3) % 250) as u16).collect();
    let mut want: Option<Vec<u16>> = None;
    for chunk in [1usize, 4, 8, 33] {
        let mut server = Server::new(
            qm.to_decode_model(Engine::Packed),
            ServerConfig { max_batch: 1, seed: 0, prefill_chunk: chunk, ..Default::default() },
        );
        let resp = server.run(vec![Request::greedy(0, prompt.clone(), 10)]);
        assert_eq!(server.metrics.prefill_ticks, prompt.len().div_ceil(chunk));
        assert_eq!(server.metrics.prefill_tokens, prompt.len());
        match &want {
            None => want = Some(resp[0].tokens.clone()),
            Some(w) => assert_eq!(&resp[0].tokens, w, "chunk={chunk} diverged"),
        }
    }
}

#[test]
fn property_continuous_batching_equals_isolated_runs() {
    let qm = quant_model();
    check("batched == isolated (greedy, quantized engine)", 5, |g| {
        let n_reqs = g.int(2, 5);
        let reqs: Vec<Request> = (0..n_reqs)
            .map(|i| {
                let plen = g.int(1, 8);
                Request::greedy(
                    i as u64,
                    (0..plen).map(|j| ((i * 17 + j * 5) % 250) as u16).collect(),
                    g.int(2, 8),
                )
            })
            .collect();
        // Isolated.
        let isolated: Vec<Vec<u16>> = reqs
            .iter()
            .map(|r| {
                let mut s = Server::new(
                    qm.to_decode_model(Engine::Packed),
                    ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
                );
                s.run(vec![r.clone()])[0].tokens.clone()
            })
            .collect();
        // Batched.
        let mut s = Server::new(
            qm.to_decode_model(Engine::Packed),
            ServerConfig { max_batch: 3, seed: 0, ..Default::default() },
        );
        let batched = s.run(reqs);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, isolated[i], "request {i}");
        }
    });
}

#[test]
fn kv_slots_never_leak_across_requests() {
    // Two identical requests must produce identical outputs even when a
    // third, longer request shares the batch between them.
    let qm = quant_model();
    let mut server = Server::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 2, seed: 0, ..Default::default() },
    );
    let same = vec![7u16, 8, 9];
    let reqs = vec![
        Request::greedy(0, same.clone(), 6),
        Request::greedy(1, vec![100; 20], 20),
        Request::greedy(2, same.clone(), 6),
    ];
    let resps = server.run(reqs);
    assert_eq!(resps[0].tokens, resps[2].tokens, "slot reuse contaminated a request");
}

/// Drive an engine until idle, collecting every event with its step index.
fn drain(engine: &mut ServeEngine) -> Vec<(usize, Event)> {
    let mut out = Vec::new();
    let mut step = 0usize;
    while !engine.is_idle() {
        for ev in engine.step() {
            out.push((step, ev));
        }
        step += 1;
        assert!(step < 10_000, "engine failed to drain");
    }
    out
}

fn finished_of(events: &[(usize, Event)], id: u64) -> (usize, Vec<u16>, FinishReason) {
    events
        .iter()
        .find_map(|(s, ev)| match ev {
            Event::Finished { response, reason } if response.id == id => {
                Some((*s, response.tokens.clone(), *reason))
            }
            _ => None,
        })
        .unwrap_or_else(|| panic!("request {id} never finished"))
}

#[test]
fn online_submission_matches_upfront_submission() {
    // Acceptance (a): a request submitted after step() has begun completes
    // with identical tokens to one submitted up front, on the real packed
    // engine.
    let qm = quant_model();
    let pa: Vec<u16> = (0..14).map(|i| ((i * 19 + 1) % 250) as u16).collect();
    let pb: Vec<u16> = vec![33, 44, 55, 66];
    let mut offline = Server::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 2, seed: 0, ..Default::default() },
    );
    let want: Vec<Vec<u16>> = offline
        .run(vec![Request::greedy(0, pa.clone(), 9), Request::greedy(1, pb.clone(), 9)])
        .into_iter()
        .map(|r| r.tokens)
        .collect();
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 2, seed: 0, ..Default::default() },
    );
    engine.submit(Request::greedy(0, pa, 9));
    let mut events: Vec<(usize, Event)> = Vec::new();
    for step in 0..4 {
        for ev in engine.step() {
            events.push((step, ev));
        }
    }
    engine.submit(Request::greedy(1, pb, 9));
    events.extend(drain(&mut engine).into_iter().map(|(s, ev)| (s + 4, ev)));
    let (_, t0, _) = finished_of(&events, 0);
    let (_, t1, _) = finished_of(&events, 1);
    assert_eq!(t0, want[0], "in-flight request perturbed by the late arrival");
    assert_eq!(t1, want[1], "mid-flight submission must match up-front submission");
}

#[test]
fn token_events_stream_incrementally() {
    // Acceptance (b): the first Token event precedes Finished by >= 1 step
    // whenever max_new > 1 — tokens are streamed as generated, not dumped
    // at completion.
    let qm = quant_model();
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
    );
    engine.submit(Request::greedy(0, vec![5, 10, 15, 20], 8));
    let events = drain(&mut engine);
    let token_steps: Vec<usize> = events
        .iter()
        .filter_map(|(s, ev)| matches!(ev, Event::Token { .. }).then_some(*s))
        .collect();
    assert_eq!(token_steps.len(), 8);
    let (finish_step, tokens, reason) = finished_of(&events, 0);
    assert_eq!(reason, FinishReason::MaxNew);
    assert!(
        token_steps[0] < finish_step,
        "first token at step {} must precede finish at step {finish_step}",
        token_steps[0]
    );
    for w in token_steps.windows(2) {
        assert_eq!(w[1], w[0] + 1, "one streamed token per decode tick");
    }
    // The stream and the final response agree exactly.
    let streamed: Vec<u16> = events
        .iter()
        .filter_map(|(_, ev)| match ev {
            Event::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(streamed, tokens);
}

#[test]
fn stop_token_requests_finish_with_stop_reason() {
    // Acceptance (c): a stop-token request finishes with FinishReason::Stop,
    // does not emit the stop token, and does not run past it.
    let qm = quant_model();
    let prompt: Vec<u16> = vec![5, 10, 15, 20];
    let mut server = Server::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
    );
    let free = server.run(vec![Request::greedy(0, prompt.clone(), 12)])[0].tokens.clone();
    assert!(free.len() >= 4, "need a few greedy tokens to pick a stop from");
    let stop = free[3];
    let cut = free.iter().position(|&t| t == stop).unwrap();
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
    );
    engine.submit(Request::greedy(0, prompt, 12).stop_tokens(vec![stop]));
    let events = drain(&mut engine);
    let (_, tokens, reason) = finished_of(&events, 0);
    assert_eq!(reason, FinishReason::Stop);
    assert_eq!(tokens, free[..cut], "generation must cut exactly at the stop token");
    assert!(!tokens.contains(&stop), "the stop token must be withheld");
    assert!(
        !events
            .iter()
            .any(|(_, ev)| matches!(ev, Event::Token { token, .. } if *token == stop)),
        "the stop token must never be streamed"
    );
}

#[test]
fn cancellation_mid_decode_returns_partial_output_and_pages() {
    let qm = quant_model();
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 2, seed: 0, ..Default::default() },
    );
    let total = engine.pool().total_pages();
    engine.submit(Request::greedy(0, vec![7, 8, 9], 20));
    engine.submit(Request::greedy(1, vec![100; 10], 6));
    // Step until request 0 has streamed a few tokens, then cancel it.
    let mut events: Vec<(usize, Event)> = Vec::new();
    let mut streamed0 = 0usize;
    let mut pre_steps = 0usize;
    for step in 0..200 {
        for ev in engine.step() {
            if matches!(ev, Event::Token { id: 0, .. }) {
                streamed0 += 1;
            }
            events.push((step, ev));
        }
        pre_steps = step + 1;
        if streamed0 >= 3 {
            break;
        }
    }
    assert!(streamed0 >= 3, "request 0 never got going");
    engine.cancel(0);
    events.extend(drain(&mut engine).into_iter().map(|(s, ev)| (s + pre_steps, ev)));
    let (_, tokens, reason) = finished_of(&events, 0);
    assert_eq!(reason, FinishReason::Cancelled);
    assert_eq!(tokens.len(), streamed0, "partial output must match what was streamed");
    let (_, t1, r1) = finished_of(&events, 1);
    assert_eq!(r1, FinishReason::MaxNew);
    assert_eq!(t1.len(), 6, "the surviving request must be untouched");
    assert!(engine.is_idle());
    assert_eq!(engine.pool().in_use_pages(), 0, "cancelled pages must be reclaimed");
    assert_eq!(engine.pool().unreserved_pages(), total, "reservation must be released");
}

#[test]
fn sampled_generation_is_seed_deterministic() {
    let qm = quant_model();
    let run = |seed: u64| -> Vec<u16> {
        let mut server =
            Server::new(
                qm.to_decode_model(Engine::Packed),
                ServerConfig { max_batch: 1, seed, ..Default::default() },
            );
        let req = Request::new(0, vec![1, 2, 3]).max_new(10).temperature(0.9).top_k(16);
        server.run(vec![req])[0].tokens.clone()
    };
    assert_eq!(run(11), run(11));
    assert_ne!(run(11), run(12), "different seeds should explore");
}
