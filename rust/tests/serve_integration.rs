//! Integration + property tests for the serving coordinator with a *real*
//! quantized model (not just the dense tiny model of the unit tests).

use nanoquant::nn::family_config;
use nanoquant::nn::model::{LayerKind, ModelParams};
use nanoquant::nn::LayerId;
use nanoquant::quant::{rank_for_bpw, Engine, LatentFactors, QuantModel};
use nanoquant::serve::{Request, Server, ServerConfig};
use nanoquant::tensor::Tensor;
use nanoquant::util::quickcheck::check;
use nanoquant::util::rng::Rng;

fn quant_model() -> QuantModel {
    let cfg = family_config("l3", "xs"); // GQA path
    let mut rng = Rng::new(0);
    let params = ModelParams::init(&cfg, &mut rng);
    let mut qm = QuantModel::from_teacher(&params);
    for bi in 0..cfg.n_layers {
        for kind in LayerKind::ALL {
            let w = params.blocks[bi].linear(kind);
            let (n, m) = (w.rows(), w.cols());
            let r = rank_for_bpw(n, m, 2.0).min(n).min(m);
            qm.set_layer(
                LayerId { block: bi, kind },
                LatentFactors {
                    u: Tensor::randn(&[n, r], 1.0, &mut rng),
                    v: Tensor::randn(&[m, r], 1.0, &mut rng),
                    s1: (0..n).map(|_| rng.uniform_in(0.01, 0.03)).collect(),
                    s2: (0..m).map(|_| rng.uniform_in(0.5, 1.5)).collect(),
                },
            );
        }
        qm.freeze_block(bi);
    }
    qm
}

#[test]
fn packed_and_naive_engines_generate_identical_greedy_output() {
    let qm = quant_model();
    let prompt: Vec<u16> = vec![5, 10, 15, 20];
    let mut out = Vec::new();
    for engine in [Engine::Packed, Engine::NaiveUnpack, Engine::Dense] {
        let mut server = Server::new(
            qm.to_decode_model(engine),
            ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
        );
        let resp = server.run(vec![Request::greedy(0, prompt.clone(), 12)]);
        out.push(resp[0].tokens.clone());
    }
    assert_eq!(out[0], out[1], "packed vs naive-unpack");
    assert_eq!(out[0], out[2], "packed vs dense(materialized)");
}

#[test]
fn chunked_prefill_is_byte_identical_on_the_packed_engine() {
    // The acceptance bar for chunked prefill, on the real packed kernels
    // (multi-token packed GEMM + chunk-wide byte LUT): any chunk size must
    // generate exactly the tokens of the one-token-per-tick path, while
    // spending ceil(prompt / chunk) prefill ticks.
    let qm = quant_model();
    let prompt: Vec<u16> = (0..33).map(|i| ((i * 11 + 3) % 250) as u16).collect();
    let mut want: Option<Vec<u16>> = None;
    for chunk in [1usize, 4, 8, 33] {
        let mut server = Server::new(
            qm.to_decode_model(Engine::Packed),
            ServerConfig { max_batch: 1, seed: 0, prefill_chunk: chunk, ..Default::default() },
        );
        let resp = server.run(vec![Request::greedy(0, prompt.clone(), 10)]);
        assert_eq!(server.metrics.prefill_ticks, prompt.len().div_ceil(chunk));
        assert_eq!(server.metrics.prefill_tokens, prompt.len());
        match &want {
            None => want = Some(resp[0].tokens.clone()),
            Some(w) => assert_eq!(&resp[0].tokens, w, "chunk={chunk} diverged"),
        }
    }
}

#[test]
fn property_continuous_batching_equals_isolated_runs() {
    let qm = quant_model();
    check("batched == isolated (greedy, quantized engine)", 5, |g| {
        let n_reqs = g.int(2, 5);
        let reqs: Vec<Request> = (0..n_reqs)
            .map(|i| {
                let plen = g.int(1, 8);
                Request::greedy(
                    i as u64,
                    (0..plen).map(|j| ((i * 17 + j * 5) % 250) as u16).collect(),
                    g.int(2, 8),
                )
            })
            .collect();
        // Isolated.
        let isolated: Vec<Vec<u16>> = reqs
            .iter()
            .map(|r| {
                let mut s = Server::new(
                    qm.to_decode_model(Engine::Packed),
                    ServerConfig { max_batch: 1, seed: 0, ..Default::default() },
                );
                s.run(vec![r.clone()])[0].tokens.clone()
            })
            .collect();
        // Batched.
        let mut s = Server::new(
            qm.to_decode_model(Engine::Packed),
            ServerConfig { max_batch: 3, seed: 0, ..Default::default() },
        );
        let batched = s.run(reqs);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, isolated[i], "request {i}");
        }
    });
}

#[test]
fn kv_slots_never_leak_across_requests() {
    // Two identical requests must produce identical outputs even when a
    // third, longer request shares the batch between them.
    let qm = quant_model();
    let mut server = Server::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 2, seed: 0, ..Default::default() },
    );
    let same = vec![7u16, 8, 9];
    let reqs = vec![
        Request::greedy(0, same.clone(), 6),
        Request::greedy(1, vec![100; 20], 20),
        Request::greedy(2, same.clone(), 6),
    ];
    let resps = server.run(reqs);
    assert_eq!(resps[0].tokens, resps[2].tokens, "slot reuse contaminated a request");
}

#[test]
fn sampled_generation_is_seed_deterministic() {
    let qm = quant_model();
    let run = |seed: u64| -> Vec<u16> {
        let mut server =
            Server::new(
                qm.to_decode_model(Engine::Packed),
                ServerConfig { max_batch: 1, seed, ..Default::default() },
            );
        let req =
            Request { id: 0, prompt: vec![1, 2, 3], max_new: 10, temperature: 0.9, top_k: 16 };
        server.run(vec![req])[0].tokens.clone()
    };
    assert_eq!(run(11), run(11));
    assert_ne!(run(11), run(12), "different seeds should explore");
}
