//! Integration tests for the `model::store` subsystem and the
//! multi-model gateway:
//!
//! - the committed golden NANOQCK2 fixture (format pin: reader drift
//!   breaks the build here and in the `artifacts-check` CI step),
//! - mmap-vs-heap byte identity of packed-model generations,
//! - hot load / serve / unload of a second model through a real loopback
//!   gateway with interleaved SSE streams, and the KV pool returning to
//!   fully-free after the unload drain.

use nanoquant::model::packed::quantized_zoo_model;
use nanoquant::model::{load_packed_model, save_packed_model, Artifact, Backing};
use nanoquant::nn::decode::{dense_decode_model, generate_greedy};
use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::quant::Engine as QuantEngine;
use nanoquant::serve::http::{Gateway, GatewayConfig};
use nanoquant::serve::{Engine, ServerConfig};
use nanoquant::util::json::Json;
use nanoquant::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

const GOLDEN: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/tiny.nqck");
const IO_TIMEOUT: Duration = Duration::from_secs(30);

// ---- shared helpers -----------------------------------------------------

/// Run `body` on a helper thread; panic if it takes longer than `secs`.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, body: F) {
    let (tx, rx) = mpsc::channel();
    let worker = std::thread::spawn(move || {
        body();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => {
            if let Err(payload) = worker.join() {
                std::panic::resume_unwind(payload);
            }
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(payload) = worker.join() {
                std::panic::resume_unwind(payload);
            }
            unreachable!("worker dropped its channel without panicking");
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("test exceeded its {secs}s watchdog");
        }
    }
}

fn connect(addr: SocketAddr) -> TcpStream {
    let stream = TcpStream::connect(addr).expect("connect to loopback gateway");
    stream.set_read_timeout(Some(IO_TIMEOUT)).unwrap();
    stream.set_write_timeout(Some(IO_TIMEOUT)).unwrap();
    stream
}

fn write_request(w: &mut impl Write, method: &str, target: &str, body: &str, close: bool) {
    write!(
        w,
        "{method} {target} HTTP/1.1\r\nHost: loopback\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{body}",
        body.len(),
        if close { "close" } else { "keep-alive" },
    )
    .expect("request write");
}

fn read_response(reader: &mut BufReader<TcpStream>) -> (u16, Json) {
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {line:?}"));
    let mut content_length = 0usize;
    loop {
        line.clear();
        reader.read_line(&mut line).expect("header line");
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().expect("content-length value");
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("response body");
    let body = String::from_utf8(body).expect("utf8 body");
    (status, Json::parse(&body).unwrap_or_else(|e| panic!("bad body JSON ({e}): {body}")))
}

fn oneshot(addr: SocketAddr, method: &str, target: &str, body: &str) -> (u16, Json) {
    let mut stream = connect(addr);
    write_request(&mut stream, method, target, body, true);
    read_response(&mut BufReader::new(stream))
}

fn open_sse(addr: SocketAddr, body: &str) -> BufReader<TcpStream> {
    let mut stream = connect(addr);
    write_request(&mut stream, "POST", "/v1/generate?stream=1", body, true);
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).expect("SSE status line");
    assert!(line.starts_with("HTTP/1.1 200"), "unexpected SSE status: {line:?}");
    loop {
        line.clear();
        reader.read_line(&mut line).expect("SSE header line");
        if line.trim_end().is_empty() {
            return reader;
        }
    }
}

fn next_frame(reader: &mut BufReader<TcpStream>) -> Option<Json> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("SSE frame line");
        if n == 0 {
            return None;
        }
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            continue;
        }
        let payload = trimmed.strip_prefix("data: ").expect("SSE line must be a data field");
        return Some(Json::parse(payload).expect("frame payload must be JSON"));
    }
}

// ---- golden fixture (format pin) ----------------------------------------

/// The closed-form payload patterns `make_tiny_nqck.py` writes.
fn golden_f32(name: &str, count: usize) -> Vec<f32> {
    let seed = name.bytes().map(|b| b as usize).sum::<usize>() % 13;
    (0..count).map(|i| ((i * 7 + seed) % 13) as f32 * 0.25 - 1.5).collect()
}

#[test]
fn golden_fixture_parses_with_exact_payloads() {
    let a = Artifact::open(GOLDEN, Backing::Heap, true).expect("golden fixture must parse");
    assert_eq!(a.kind(), "packed-model");
    let cfg = a.header().get("config").expect("config");
    assert_eq!(cfg.get("name").and_then(Json::as_str), Some("golden-tiny"));
    assert_eq!(cfg.get("d_model").and_then(Json::as_usize), Some(8));
    assert_eq!(a.tensors().len(), 14);
    for t in a.tensors() {
        assert_eq!(t.offset % 64, 0, "{} misaligned", t.name);
    }
    // Every f32 payload matches its generator pattern bit for bit.
    for (name, count) in [
        ("embed", 32 * 8),
        ("b0.ln1", 8),
        ("b0.wq.s1", 8),
        ("b0.wq.s2", 8),
        ("b0.wk.w", 8 * 8),
        ("b0.wv.w", 8 * 8),
        ("b0.wo.w", 8 * 8),
        ("b0.wg.w", 16 * 8),
        ("b0.wu.w", 16 * 8),
        ("b0.wd.w", 8 * 16),
        ("b0.ln2", 8),
        ("ln_f", 8),
    ] {
        let got = a.f32_view(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(&got[..], &golden_f32(name, count)[..], "{name} payload drifted");
    }
    // The packed sign words too.
    let u = a.bits_view("b0.wq.u").unwrap();
    let want_u: Vec<u32> = (0..8).map(|i| (i * 5 + 3) & 0xF).collect();
    assert_eq!(&u[..], &want_u[..], "b0.wq.u words drifted");
    let vt = a.bits_view("b0.wq.vt").unwrap();
    let want_vt: Vec<u32> = (0..4).map(|i| (i * 11 + 1) & 0xFF).collect();
    assert_eq!(&vt[..], &want_vt[..], "b0.wq.vt words drifted");
}

#[test]
fn golden_fixture_serves_identically_from_mmap_and_heap() {
    let heap = load_packed_model(GOLDEN, Backing::Heap, true).expect("heap load");
    let mapped = load_packed_model(GOLDEN, Backing::Mmap, true).expect("mmap load");
    assert_eq!(heap.quantized_layers, 1);
    let prompt: Vec<u16> = vec![1, 2, 3];
    let a = generate_greedy(&heap.model, &prompt, 6, &[]);
    let b = generate_greedy(&mapped.model, &prompt, 6, &[]);
    assert_eq!(a, b, "mmap and heap generations must be byte-identical");
    assert_eq!(a.len(), 6);
}

// ---- mmap vs heap byte identity on a quantized zoo model ----------------

#[test]
fn quantized_zoo_artifact_roundtrips_byte_identically() {
    let qm = quantized_zoo_model(0xA11CE);
    let path = "/tmp/nanoquant_it_store_roundtrip.nqck";
    save_packed_model(path, &qm).unwrap();
    let reference = qm.to_decode_model(QuantEngine::Packed);
    let heap = load_packed_model(path, Backing::Heap, true).unwrap();
    let mapped = load_packed_model(path, Backing::Mmap, true).unwrap();
    for prompt in [vec![7u16], vec![1, 2, 3, 4, 5, 6, 7, 8], vec![250, 0, 13]] {
        let want = generate_greedy(&reference, &prompt, 10, &[]);
        assert_eq!(generate_greedy(&heap.model, &prompt, 10, &[]), want, "heap diverged");
        assert_eq!(generate_greedy(&mapped.model, &prompt, 10, &[]), want, "mmap diverged");
    }
    std::fs::remove_file(path).ok();
}

// ---- multi-model gateway over loopback HTTP -----------------------------

fn dense_tiny_engine(scfg: ServerConfig) -> Engine {
    let mcfg = family_config("l2", "xs");
    let mut rng = Rng::new(0);
    let params = ModelParams::init(&mcfg, &mut rng);
    Engine::new(dense_decode_model(&params), scfg)
}

#[test]
fn gateway_hot_loads_serves_two_models_concurrently_and_unloads_clean() {
    with_watchdog(180, || {
        let path = "/tmp/nanoquant_it_gateway_second_model.nqck";
        save_packed_model(path, &quantized_zoo_model(77)).unwrap();

        let scfg = ServerConfig { max_batch: 2, seed: 0, ..Default::default() };
        let gateway = Gateway::start(
            dense_tiny_engine(scfg),
            GatewayConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .expect("gateway must bind");
        let addr = gateway.local_addr();

        // Before the load, the named model is unroutable.
        let (status, json) =
            oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [1], \"model\": \"packed\"}");
        assert_eq!(status, 404, "{json:?}");

        // Hot-load the packed artifact as a second model.
        let body = format!(
            "{{\"name\": \"packed\", \"path\": {path:?}, \"backing\": \"mmap\", \"max_batch\": 2}}"
        );
        let (status, json) = oneshot(addr, "POST", "/v1/models/load", &body);
        assert_eq!(status, 200, "{json:?}");
        assert_eq!(json.get("loaded").and_then(Json::as_bool), Some(true));
        // Duplicate load of the same name is a 409.
        let (status, _) = oneshot(addr, "POST", "/v1/models/load", &body);
        assert_eq!(status, 409);

        // /v1/models lists both slots, default flagged.
        let (status, json) = oneshot(addr, "GET", "/v1/models", "");
        assert_eq!(status, 200);
        let models = json.get("models").and_then(Json::as_arr).expect("models array");
        assert_eq!(models.len(), 2, "{json:?}");
        assert_eq!(json.get("default").and_then(Json::as_str), Some("default"));

        // Interleaved SSE streams against both models at once: read the
        // two streams frame by frame, alternating, until both finish.
        let want_default = {
            let mcfg = family_config("l2", "xs");
            let mut rng = Rng::new(0);
            let params = ModelParams::init(&mcfg, &mut rng);
            generate_greedy(&dense_decode_model(&params), &[5, 6, 7], 8, &[])
        };
        let want_packed = {
            let loaded = load_packed_model(path, Backing::Heap, true).unwrap();
            generate_greedy(&loaded.model, &[5, 6, 7], 8, &[])
        };
        let mut sse_a = open_sse(addr, "{\"prompt\": [5, 6, 7], \"max_new\": 8}");
        let mut sse_b =
            open_sse(addr, "{\"prompt\": [5, 6, 7], \"max_new\": 8, \"model\": \"packed\"}");
        let mut toks_a: Vec<u16> = Vec::new();
        let mut toks_b: Vec<u16> = Vec::new();
        let (mut done_a, mut done_b) = (false, false);
        while !(done_a && done_b) {
            for (done, reader, toks) in
                [(&mut done_a, &mut sse_a, &mut toks_a), (&mut done_b, &mut sse_b, &mut toks_b)]
            {
                if *done {
                    continue;
                }
                let frame = next_frame(reader).expect("stream ended without done frame");
                if frame.get("done").and_then(Json::as_bool) == Some(true) {
                    *done = true;
                } else if let Some(t) = frame.get("token").and_then(Json::as_usize) {
                    toks.push(t as u16);
                }
            }
        }
        assert_eq!(toks_a, want_default, "default model stream diverged under interleaving");
        assert_eq!(toks_b, want_packed, "packed model stream diverged under interleaving");

        // Unload while a request is mid-flight: kick off a long SSE
        // generation on the packed model, see two tokens, then unload.
        // The drain must let it run to completion before the weights go.
        let body = "{\"prompt\": [9, 9], \"max_new\": 16, \"model\": \"packed\"}";
        let mut sse = open_sse(addr, body);
        let mut seen = 0usize;
        while seen < 2 {
            let frame = next_frame(&mut sse).expect("stream ended early");
            if frame.get("token").is_some() {
                seen += 1;
            }
        }
        let (status, json) = oneshot(addr, "POST", "/v1/models/unload", "{\"name\": \"packed\"}");
        assert_eq!(status, 200, "{json:?}");
        assert_eq!(json.get("unloaded").and_then(Json::as_bool), Some(true));
        let final_snap = json.get("final").expect("final snapshot");
        // The acceptance bar: after the drain the pool is fully free.
        let kv = final_snap.get("kv_pool").expect("kv_pool");
        assert_eq!(kv.get("reserved_pages").and_then(Json::as_usize), Some(0), "{json:?}");
        assert_eq!(kv.get("in_use_pages").and_then(Json::as_usize), Some(0), "{json:?}");
        assert_eq!(final_snap.get("in_flight").and_then(Json::as_usize), Some(0));
        // The drained request streamed to completion.
        let mut total = seen;
        let mut finished = false;
        while let Some(frame) = next_frame(&mut sse) {
            if frame.get("done").and_then(Json::as_bool) == Some(true) {
                assert_eq!(frame.get("finish_reason").and_then(Json::as_str), Some("max_new"));
                finished = true;
                break;
            }
            if frame.get("token").is_some() {
                total += 1;
            }
        }
        assert!(finished, "drained stream must end with a done frame");
        assert_eq!(total, 16, "drain must let the in-flight request finish its budget");

        // The unloaded model is gone; the default keeps serving; a second
        // unload is a 404.
        let (status, _) =
            oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [1], \"model\": \"packed\"}");
        assert_eq!(status, 404);
        let (status, json) =
            oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [1, 2], \"max_new\": 3}");
        assert_eq!(status, 200);
        assert_eq!(
            json.get("tokens").and_then(Json::as_arr).map(|a| a.len()),
            Some(3),
            "{json:?}"
        );
        let (status, _) = oneshot(addr, "POST", "/v1/models/unload", "{\"name\": \"packed\"}");
        assert_eq!(status, 404);

        gateway.shutdown();
        std::fs::remove_file(path).ok();
    });
}

#[test]
fn gateway_metrics_report_per_model_and_default_compat() {
    with_watchdog(120, || {
        let path = "/tmp/nanoquant_it_gateway_metrics_model.nqck";
        save_packed_model(path, &quantized_zoo_model(31)).unwrap();
        let gateway = Gateway::start(
            dense_tiny_engine(ServerConfig::default()),
            GatewayConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .expect("gateway must bind");
        let addr = gateway.local_addr();
        let body = format!("{{\"name\": \"b\", \"path\": {path:?}}}");
        let (status, _) = oneshot(addr, "POST", "/v1/models/load", &body);
        assert_eq!(status, 200);
        // Generate on each model.
        let (status, _) =
            oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [1], \"max_new\": 2}");
        assert_eq!(status, 200);
        let (status, _) = oneshot(
            addr,
            "POST",
            "/v1/generate",
            "{\"prompt\": [1], \"max_new\": 5, \"model\": \"b\"}",
        );
        assert_eq!(status, 200);
        let (status, metrics) = oneshot(addr, "GET", "/v1/metrics", "");
        assert_eq!(status, 200);
        // Top level stays wire-compatible with the single-model gateway:
        // it is the default model's snapshot.
        assert_eq!(metrics.get("total_tokens").and_then(Json::as_usize), Some(2), "{metrics:?}");
        assert!(metrics.get("kv_pool").is_some());
        // And the per-model map carries both engines' counters.
        let models = metrics.get("models").expect("models map");
        let b = models.get("b").unwrap_or_else(|| panic!("missing model b: {metrics:?}"));
        assert_eq!(b.get("total_tokens").and_then(Json::as_usize), Some(5));
        assert_eq!(
            models.get("default").and_then(|m| m.get("total_tokens")).and_then(Json::as_usize),
            Some(2)
        );
        gateway.shutdown();
        std::fs::remove_file(path).ok();
    });
}
