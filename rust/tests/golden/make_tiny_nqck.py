#!/usr/bin/env python3
"""Regenerate tests/golden/tiny.nqck — the committed NANOQCK2 golden fixture.

The fixture is written by THIS script, independently of the Rust writer,
so it pins the on-disk format itself: if the Rust reader drifts (magic,
header fields, offset rules, alignment, CRC, payload encoding), the
`golden_fixture_*` tests and the `artifacts-check` CI step fail.

Layout under test (see rust/src/model/artifact.rs):
    magic "NANOQCK2" | u64 LE header_len | JSON header
    | zero pad to align64(16 + header_len)
    | payloads, each at a 64-byte-aligned offset relative to that base
    | u32 LE CRC-32 (IEEE) over every preceding byte

Model: a deliberately tiny packed model (kind "packed-model") with one
block whose wq is quantized (b1 sign factors + f32 scales) and every
other linear dense. All payload values follow closed-form patterns that
rust/tests/model_store.rs recomputes exactly:
    f32 tensor named N:  x[i] = ((i*7 + seed(N)) % 13) * 0.25 - 1.5
                         seed(N) = sum(bytes of N) % 13
    b1  "...u"  words:   w[i] = (i*5 + 3)  & 0xF   (cols=4)
    b1  "...vt" words:   w[i] = (i*11 + 1) & 0xFF  (cols=8)
"""
import binascii
import json
import struct

ALIGN = 64

CONFIG = {
    "name": "golden-tiny",
    "vocab": 32,
    "d_model": 8,
    "n_layers": 1,
    "n_heads": 2,
    "n_kv_heads": 2,
    "d_ff": 16,
    "max_seq": 16,
    "rope_theta": 10000.0,
    "tied": True,
    "eps": 0.001,
}


def f32_pattern(name, count):
    seed = sum(name.encode()) % 13
    return [((i * 7 + seed) % 13) * 0.25 - 1.5 for i in range(count)]


def u_words(count):
    return [(i * 5 + 3) & 0xF for i in range(count)]


def vt_words(count):
    return [(i * 11 + 1) & 0xFF for i in range(count)]


def main():
    tensors = []  # (name, dtype, shape, payload_bytes)

    def add_f32(name, shape):
        n = 1
        for d in shape:
            n *= d
        data = struct.pack("<%df" % n, *f32_pattern(name, n))
        tensors.append((name, "f32", shape, data))

    def add_b1(name, rows, cols, words):
        assert len(words) == rows * ((cols + 31) // 32)
        data = struct.pack("<%dI" % len(words), *words)
        tensors.append((name, "b1", [rows, cols], data))

    d, dff, vocab = CONFIG["d_model"], CONFIG["d_ff"], CONFIG["vocab"]
    kv = CONFIG["n_kv_heads"] * (d // CONFIG["n_heads"])
    add_f32("embed", [vocab, d])
    add_f32("b0.ln1", [d])
    # wq quantized at rank 4: u [d, 4] (1 word/row), vt [4, d] (1 word/row).
    add_b1("b0.wq.u", d, 4, u_words(d))
    add_b1("b0.wq.vt", 4, d, vt_words(4))
    add_f32("b0.wq.s1", [d])
    add_f32("b0.wq.s2", [d])
    for name, shape in [
        ("b0.wk.w", [kv, d]),
        ("b0.wv.w", [kv, d]),
        ("b0.wo.w", [d, d]),
        ("b0.wg.w", [dff, d]),
        ("b0.wu.w", [dff, d]),
        ("b0.wd.w", [d, dff]),
    ]:
        add_f32(name, shape)
    add_f32("b0.ln2", [d])
    add_f32("ln_f", [d])

    manifest, cursor = [], 0
    for name, dtype, shape, data in tensors:
        offset = (cursor + ALIGN - 1) // ALIGN * ALIGN
        manifest.append(
            {"name": name, "dtype": dtype, "shape": shape, "offset": offset, "bytes": len(data)}
        )
        cursor = offset + len(data)

    header = json.dumps(
        {"kind": "packed-model", "version": 2, "config": CONFIG, "tensors": manifest}
    ).encode()

    out = bytearray()
    out += b"NANOQCK2"
    out += struct.pack("<Q", len(header))
    out += header
    base = (len(out) + ALIGN - 1) // ALIGN * ALIGN
    out += b"\0" * (base - len(out))
    for (name, _, _, data), entry in zip(tensors, manifest):
        want = base + entry["offset"]
        assert want >= len(out), name
        out += b"\0" * (want - len(out))
        out += data
    out += struct.pack("<I", binascii.crc32(bytes(out)) & 0xFFFFFFFF)

    with open("tiny.nqck", "wb") as f:
        f.write(bytes(out))
    print("wrote tiny.nqck (%d bytes, %d tensors)" % (len(out), len(tensors)))


if __name__ == "__main__":
    main()
