//! L2 ↔ L3 parity: the AOT JAX/Pallas artifacts must reproduce the native
//! Rust implementation bit-closely. Requires `make artifacts` (tests skip
//! with a notice when the artifact directory is absent).

use nanoquant::nn::decode::{decode_step, dense_decode_model, KvCache};
use nanoquant::nn::family_config;
use nanoquant::nn::model::{model_forward, LayerKind, ModelParams};
use nanoquant::nn::LayerId;
use nanoquant::quant::{rank_for_bpw, Engine, LatentFactors, PackedLinear, QuantModel};
use nanoquant::runtime::{
    flatten_dense_params, flatten_quant_params, kv_cache_literal, literal_f32, packed_literal,
    scalar_i32, tokens_literal, vec_literal, Literal, Runtime,
};
use nanoquant::tensor::Tensor;
use nanoquant::util::rng::Rng;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn runtime_or_skip() -> Option<Runtime> {
    if !std::path::Path::new(ARTIFACTS).join("manifest.json").exists() {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        return None;
    }
    match Runtime::new(ARTIFACTS) {
        Ok(rt) if rt.can_execute() => Some(rt),
        Ok(_) => {
            eprintln!("[skip] artifacts present but this build has no pjrt backend");
            None
        }
        Err(e) => {
            eprintln!("[skip] artifacts present but runtime unavailable: {e}");
            None
        }
    }
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: rust={x} artifact={y}"
        );
    }
}

/// The artifact config: l2-s, batch 1, seq 64, bpw 1.0 (see aot.py).
fn artifact_model() -> ModelParams {
    let cfg = family_config("l2", "s");
    let mut rng = Rng::new(42);
    ModelParams::init(&cfg, &mut rng)
}

fn random_quant_model(params: &ModelParams, seed: u64) -> QuantModel {
    let mut qm = QuantModel::from_teacher(params);
    let mut rng = Rng::new(seed);
    for bi in 0..params.cfg.n_layers {
        for kind in LayerKind::ALL {
            let w = params.blocks[bi].linear(kind);
            let (n, m) = (w.rows(), w.cols());
            let r = rank_for_bpw(n, m, 1.0).min(n).min(m);
            qm.set_layer(
                LayerId { block: bi, kind },
                LatentFactors {
                    u: Tensor::randn(&[n, r], 1.0, &mut rng),
                    v: Tensor::randn(&[m, r], 1.0, &mut rng),
                    s1: (0..n).map(|_| rng.uniform_in(0.005, 0.02)).collect(),
                    s2: (0..m).map(|_| rng.uniform_in(0.5, 1.5)).collect(),
                },
            );
        }
        qm.freeze_block(bi);
    }
    qm
}

#[test]
fn dense_forward_parity() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let params = artifact_model();
    let (batch, seq) = (1usize, 64usize);
    let tokens: Vec<u16> = (0..seq).map(|i| ((i * 37 + 11) % 256) as u16).collect();

    let (native, _) = model_forward(&params, &tokens, batch, seq, false);

    let mut args = flatten_dense_params(&params).unwrap();
    args.push(tokens_literal(&tokens, batch, seq).unwrap());
    let out = rt.execute("l2_s_fwd_dense", &args).expect("execute");
    let logits = literal_f32(&out[0]).unwrap();

    assert_eq!(logits.len(), native.numel());
    assert_close(&native.data, &logits, 2e-3, "dense fwd logits");
}

#[test]
fn quant_forward_parity_pallas_kernels() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let params = artifact_model();
    let (batch, seq) = (1usize, 64usize);
    let tokens: Vec<u16> = (0..seq).map(|i| ((i * 53 + 5) % 256) as u16).collect();
    let qm = random_quant_model(&params, 7);

    // Native reference: materialized dense forward.
    let (native, _) = model_forward(&qm.params, &tokens, batch, seq, false);

    let mut args = flatten_quant_params(&qm).unwrap();
    args.push(tokens_literal(&tokens, batch, seq).unwrap());
    let out = rt.execute("l2_s_fwd_quant", &args).expect("execute quant fwd");
    let logits = literal_f32(&out[0]).unwrap();
    assert_close(&native.data, &logits, 5e-3, "quant fwd logits (pallas)");
}

#[test]
fn dense_decode_parity_with_kv_cache() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let params = artifact_model();
    let cfg = &params.cfg;
    let tokens: Vec<u16> = vec![17, 3, 250, 88, 4];

    // Native incremental decode.
    let dm = dense_decode_model(&params);
    let mut cache = KvCache::new(cfg);

    // Artifact decode loop: KV caches round-trip as literals.
    let flat = flatten_dense_params(&params).unwrap();
    let mut k_cache = kv_cache_literal(cfg).unwrap();
    let mut v_cache = kv_cache_literal(cfg).unwrap();
    for (pos, &tok) in tokens.iter().enumerate() {
        let native_logits = decode_step(&dm, &mut cache, tok);

        let mut args: Vec<Literal> = flat.iter().map(clone_lit).collect();
        args.push(scalar_i32(tok as i32));
        args.push(scalar_i32(pos as i32));
        args.push(clone_lit(&k_cache));
        args.push(clone_lit(&v_cache));
        let mut out = rt.execute("l2_s_decode_dense", &args).expect("decode step");
        let logits = literal_f32(&out[0]).unwrap();
        v_cache = out.pop().unwrap();
        k_cache = out.pop().unwrap();

        assert_close(&native_logits, &logits, 2e-3, &format!("decode logits @{pos}"));
    }
}

#[test]
fn gemv_kernel_artifact_matches_rust_packed_kernel() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (n, m, r) = (256usize, 256usize, 112usize);
    let mut rng = Rng::new(3);
    let lat = LatentFactors {
        u: Tensor::randn(&[n, r], 1.0, &mut rng),
        v: Tensor::randn(&[m, r], 1.0, &mut rng),
        s1: (0..n).map(|_| rng.uniform_in(0.2, 2.0)).collect(),
        s2: (0..m).map(|_| rng.uniform_in(0.2, 2.0)).collect(),
    };
    let q = lat.freeze();
    let x: Vec<f32> = rng.normal_vec(m, 1.0);

    let native = PackedLinear::new(q.clone()).forward_vec(&x);

    for engine in ["pallas", "naive"] {
        let args = vec![
            packed_literal(&q.u).unwrap(),
            packed_literal(&q.vt).unwrap(),
            vec_literal(&q.s1),
            vec_literal(&q.s2),
            vec_literal(&x),
        ];
        let out = rt
            .execute(&format!("gemv_{n}x{m}x{r}_{engine}"), &args)
            .unwrap_or_else(|e| panic!("gemv {engine}: {e}"));
        let y = literal_f32(&out[0]).unwrap();
        assert_close(&native, &y, 1e-2, &format!("gemv {engine}"));
    }
}

#[test]
fn quant_decode_engines_agree() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let params = artifact_model();
    let cfg = &params.cfg;
    let qm = random_quant_model(&params, 9);

    // Native packed-engine decode.
    let dm = qm.to_decode_model(Engine::Packed);
    let mut cache = KvCache::new(cfg);
    let tok = 99u16;
    let native = decode_step(&dm, &mut cache, tok);

    // Both quantized decode artifacts must agree with it.
    let flat = flatten_quant_params(&qm).unwrap();
    for name in ["l2_s_decode_quant", "l2_s_decode_naive"] {
        let mut args: Vec<Literal> = flat.iter().map(clone_lit).collect();
        args.push(scalar_i32(tok as i32));
        args.push(scalar_i32(0));
        args.push(kv_cache_literal(cfg).unwrap());
        args.push(kv_cache_literal(cfg).unwrap());
        let out = rt.execute(name, &args).unwrap_or_else(|e| panic!("{name}: {e}"));
        let logits = literal_f32(&out[0]).unwrap();
        assert_close(&native, &logits, 5e-3, name);
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    // Needs only the manifest (plain JSON), not a pjrt backend.
    if !std::path::Path::new(ARTIFACTS).join("manifest.json").exists() {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        return;
    }
    let rt = Runtime::new(ARTIFACTS).expect("manifest load");
    let names = rt.available();
    for expect in [
        "l2_s_fwd_dense",
        "l2_s_fwd_quant",
        "l2_s_decode_dense",
        "l2_s_decode_quant",
        "l2_s_decode_naive",
        "gemv_256x256x112_pallas",
    ] {
        assert!(names.iter().any(|n| n == expect), "missing artifact {expect}");
    }
}

/// Copy a literal by value (the offline `runtime::Literal` is `Clone`; the
/// xla crate's is not, so call sites go through this helper either way).
fn clone_lit(l: &Literal) -> Literal {
    l.clone()
}
