//! Loopback integration tests for the HTTP/SSE gateway: a real
//! `TcpListener`, real `TcpStream` clients, and the full
//! parse → bridge → engine → SSE path.
//!
//! Every test body runs under a watchdog thread so a hung listener or a
//! stalled stream fails fast instead of wedging the test job (CI also has
//! a job-level timeout as the outer belt).

use nanoquant::nn::decode::dense_decode_model;
use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::serve::http::traffic::{run_traffic, TrafficConfig};
use nanoquant::serve::http::{Gateway, GatewayConfig};
use nanoquant::serve::{Engine, FinishReason, Request, Server, ServerConfig, SloClass};
use nanoquant::util::json::Json;
use nanoquant::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

const IO_TIMEOUT: Duration = Duration::from_secs(30);

fn tiny_model() -> nanoquant::nn::decode::DecodeModel {
    let mcfg = family_config("l2", "xs");
    let mut rng = Rng::new(0);
    let params = ModelParams::init(&mcfg, &mut rng);
    dense_decode_model(&params)
}

fn start_gateway(scfg: ServerConfig, gcfg: GatewayConfig) -> Gateway {
    let gcfg = GatewayConfig { addr: "127.0.0.1:0".into(), ..gcfg };
    Gateway::start(Engine::new(tiny_model(), scfg), gcfg).expect("gateway must bind")
}

/// Run `body` on a helper thread; panic if it takes longer than `secs`.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, body: F) {
    let (tx, rx) = mpsc::channel();
    let worker = std::thread::spawn(move || {
        body();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => {
            if let Err(payload) = worker.join() {
                std::panic::resume_unwind(payload);
            }
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(payload) = worker.join() {
                std::panic::resume_unwind(payload);
            }
            unreachable!("worker dropped its channel without panicking");
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("test exceeded its {secs}s watchdog (hung listener or stalled stream?)");
        }
    }
}

fn connect(addr: SocketAddr) -> TcpStream {
    let stream = TcpStream::connect(addr).expect("connect to loopback gateway");
    stream.set_read_timeout(Some(IO_TIMEOUT)).unwrap();
    stream.set_write_timeout(Some(IO_TIMEOUT)).unwrap();
    stream
}

/// Write one request on an open connection (keep-alive framing).
fn write_request(w: &mut impl Write, method: &str, target: &str, body: &str, close: bool) {
    write!(
        w,
        "{method} {target} HTTP/1.1\r\nHost: loopback\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{body}",
        body.len(),
        if close { "close" } else { "keep-alive" },
    )
    .expect("request write");
}

/// Read one `Content-Length`-framed response; returns (status, body JSON).
fn read_response(reader: &mut BufReader<TcpStream>) -> (u16, Json) {
    let (status, _, json) = read_response_headed(reader);
    (status, json)
}

/// Like [`read_response`] but also returns the response headers (names
/// lower-cased), so reject tests can assert `Retry-After`.
fn read_response_headed(reader: &mut BufReader<TcpStream>) -> (u16, Vec<(String, String)>, Json) {
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {line:?}"));
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        line.clear();
        reader.read_line(&mut line).expect("header line");
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().expect("content-length value");
            }
            headers.push((name, value));
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("response body");
    let body = String::from_utf8(body).expect("utf8 body");
    let json = Json::parse(&body).unwrap_or_else(|e| panic!("bad body JSON ({e}): {body}"));
    (status, headers, json)
}

fn retry_after(headers: &[(String, String)]) -> Option<&str> {
    headers.iter().find(|(n, _)| n == "retry-after").map(|(_, v)| v.as_str())
}

/// One-shot request on a fresh connection.
fn oneshot(addr: SocketAddr, method: &str, target: &str, body: &str) -> (u16, Json) {
    let mut stream = connect(addr);
    write_request(&mut stream, method, target, body, true);
    read_response(&mut BufReader::new(stream))
}

/// Open an SSE generate stream and return the reader positioned after the
/// response head.
fn open_sse(addr: SocketAddr, body: &str) -> BufReader<TcpStream> {
    let mut stream = connect(addr);
    write_request(&mut stream, "POST", "/v1/generate?stream=1", body, true);
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).expect("SSE status line");
    assert!(line.starts_with("HTTP/1.1 200"), "unexpected SSE status: {line:?}");
    loop {
        line.clear();
        reader.read_line(&mut line).expect("SSE header line");
        if line.trim_end().is_empty() {
            return reader;
        }
    }
}

/// Read the next `data:` frame, or `None` at end of stream.
fn next_frame(reader: &mut BufReader<TcpStream>) -> Option<Json> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("SSE frame line");
        if n == 0 {
            return None;
        }
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            continue;
        }
        let payload = trimmed.strip_prefix("data: ").expect("SSE line must be a data field");
        return Some(Json::parse(payload).expect("frame payload must be JSON"));
    }
}

/// Drain an SSE stream: (streamed tokens, final `done` frame).
fn drain_sse(reader: &mut BufReader<TcpStream>) -> (Vec<u16>, Json) {
    let mut tokens = Vec::new();
    while let Some(frame) = next_frame(reader) {
        if frame.get("done").and_then(Json::as_bool) == Some(true) {
            return (tokens, frame);
        }
        if let Some(tok) = frame.get("token").and_then(Json::as_usize) {
            tokens.push(tok as u16);
        }
    }
    panic!("SSE stream ended without a done frame (streamed {} tokens)", tokens.len());
}

fn frame_tokens(frame: &Json, key: &str) -> Vec<u16> {
    frame
        .get(key)
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("frame missing {key}: {frame:?}"))
        .iter()
        .map(|t| t.as_usize().expect("token must be an integer") as u16)
        .collect()
}

/// Poll `/v1/metrics` until `pred` holds; panics after `secs`.
fn wait_metrics(addr: SocketAddr, secs: u64, why: &str, pred: impl Fn(&Json) -> bool) -> Json {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        let (status, metrics) = oneshot(addr, "GET", "/v1/metrics", "");
        assert_eq!(status, 200);
        if pred(&metrics) {
            return metrics;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {why}: {metrics:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Total admission-queue depth across all classes.
fn queue_depth(metrics: &Json) -> usize {
    SloClass::ALL
        .iter()
        .map(|class| {
            metrics
                .get("queue_depth")
                .and_then(|d| d.get(class.as_str()))
                .and_then(Json::as_usize)
                .unwrap_or_else(|| panic!("metrics missing queue_depth.{}", class.as_str()))
        })
        .sum()
}

/// Consume SSE frames until the first token arrives (request is running).
fn wait_first_token(reader: &mut BufReader<TcpStream>) {
    loop {
        let frame = next_frame(reader).expect("stream ended before first token");
        assert_ne!(
            frame.get("done").and_then(Json::as_bool),
            Some(true),
            "request finished before first token: {frame:?}"
        );
        if frame.get("token").is_some() {
            return;
        }
    }
}

fn kv_pool_field(metrics: &Json, key: &str) -> usize {
    metrics
        .get("kv_pool")
        .and_then(|p| p.get(key))
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("metrics missing kv_pool.{key}: {metrics:?}"))
}

#[test]
fn sse_stream_is_byte_identical_to_offline_server() {
    with_watchdog(120, || {
        let scfg = ServerConfig { max_batch: 2, seed: 0, ..Default::default() };
        let prompt: Vec<u16> = (0..9).map(|i| ((i * 23 + 1) % 250) as u16).collect();
        // Reference: the offline Server::run loop on an identical engine.
        let want = Server::new(tiny_model(), scfg.clone())
            .run(vec![Request::greedy(0, prompt.clone(), 7)])
            .remove(0);
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let body = format!(
            "{{\"prompt\": {:?}, \"max_new\": 7}}",
            prompt.iter().map(|&t| t as usize).collect::<Vec<usize>>()
        );
        let mut reader = open_sse(gateway.local_addr(), &body);
        let (streamed, done) = drain_sse(&mut reader);
        assert_eq!(streamed, want.tokens, "SSE stream diverged from Server::run");
        assert_eq!(frame_tokens(&done, "tokens"), want.tokens, "final frame token mismatch");
        assert_eq!(done.get("finish_reason").and_then(Json::as_str), Some("max_new"));
        assert_eq!(done.get("text").and_then(Json::as_str), Some(want.text.as_str()));
        assert!(done.get("ttft_s").and_then(Json::as_f64).is_some_and(|t| t >= 0.0));
        assert!(done.get("queue_s").and_then(Json::as_f64).is_some_and(|t| t >= 0.0));
        gateway.shutdown();
    });
}

#[test]
fn full_response_mode_matches_stream_mode_and_honors_stop_tokens() {
    with_watchdog(120, || {
        let scfg = ServerConfig { max_batch: 2, seed: 0, ..Default::default() };
        let prompt: Vec<u16> = vec![11, 12, 13];
        let free = Server::new(tiny_model(), scfg.clone())
            .run(vec![Request::greedy(0, prompt.clone(), 6)])
            .remove(0)
            .tokens;
        assert!(free.len() >= 3, "need a few greedy tokens to pick a stop from");
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        // Full-response mode returns exactly the greedy reference tokens.
        let body = "{\"prompt\": [11, 12, 13], \"max_new\": 6}";
        let (status, json) = oneshot(addr, "POST", "/v1/generate", body);
        assert_eq!(status, 200);
        assert_eq!(frame_tokens(&json, "tokens"), free);
        assert_eq!(json.get("finish_reason").and_then(Json::as_str), Some("max_new"));
        // A stop token cuts the generation and is withheld from the output
        // (cut at its *first* occurrence, which may precede index 2 if the
        // greedy output repeats tokens).
        let stop = free[2];
        let cut = free.iter().position(|&t| t == stop).unwrap();
        let body = format!("{{\"prompt\": [11, 12, 13], \"max_new\": 6, \"stop_tokens\": [{stop}]}}");
        let (status, json) = oneshot(addr, "POST", "/v1/generate", &body);
        assert_eq!(status, 200);
        assert_eq!(json.get("finish_reason").and_then(Json::as_str), Some("stop"));
        assert_eq!(frame_tokens(&json, "tokens"), free[..cut].to_vec());
        gateway.shutdown();
    });
}

#[test]
fn disconnect_storm_cancels_requests_and_returns_pool_to_fully_free() {
    with_watchdog(180, || {
        // A 4-page pool (the clamp minimum) and requests whose footprint
        // reserves all of it: a disconnect that leaked pages would
        // permanently wedge admission.
        let scfg = ServerConfig {
            max_batch: 2,
            seed: 0,
            kv_pages: Some(4),
            ..Default::default()
        };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        let prompt_json: Vec<usize> = (0..40).map(|j| j % 250).collect();
        let body = format!("{{\"prompt\": {prompt_json:?}, \"max_new\": 200}}");
        const STORM: usize = 3;
        for round in 0..STORM {
            let mut reader = open_sse(addr, &body);
            let mut streamed = 0usize;
            while streamed < 3 {
                let frame = next_frame(&mut reader)
                    .unwrap_or_else(|| panic!("round {round}: stream ended early"));
                assert_ne!(
                    frame.get("done").and_then(Json::as_bool),
                    Some(true),
                    "round {round}: finished before the disconnect"
                );
                if frame.get("token").is_some() {
                    streamed += 1;
                }
            }
            // Drop the connection mid-stream: the handler's next frame
            // write fails and must become an engine cancel.
            drop(reader);
        }
        // Observe through the public metrics endpoint only.
        let deadline = Instant::now() + Duration::from_secs(60);
        let metrics = loop {
            let (status, metrics) = oneshot(addr, "GET", "/v1/metrics", "");
            assert_eq!(status, 200);
            let cancellations =
                metrics.get("cancellations").and_then(Json::as_usize).expect("cancellations");
            if cancellations == STORM {
                break metrics;
            }
            assert!(
                Instant::now() < deadline,
                "cancellations stuck at {cancellations}/{STORM}: {metrics:?}"
            );
            std::thread::sleep(Duration::from_millis(20));
        };
        // FinishReason::Cancelled is what increments this counter — one
        // per dropped connection, none double-counted.
        assert_eq!(metrics.get("cancellations").and_then(Json::as_usize), Some(STORM));
        // The pool is fully free again: nothing reserved, nothing attached,
        // every touched page back on the recycle list.
        assert_eq!(kv_pool_field(&metrics, "reserved_pages"), 0);
        assert_eq!(kv_pool_field(&metrics, "in_use_pages"), 0);
        assert!(kv_pool_field(&metrics, "free_pages") > 0);
        assert_eq!(metrics.get("in_flight").and_then(Json::as_usize), Some(0));
        // Behavioral proof: a fresh whole-budget request is admitted and
        // completes (a single leaked page would defer it forever).
        let body = format!("{{\"prompt\": {prompt_json:?}, \"max_new\": 8}}");
        let (status, json) = oneshot(addr, "POST", "/v1/generate", &body);
        assert_eq!(status, 200);
        assert_eq!(frame_tokens(&json, "tokens").len(), 8);
        gateway.shutdown();
    });
}

#[test]
fn cancel_endpoint_finishes_request_with_cancelled_reason() {
    with_watchdog(120, || {
        use nanoquant::serve::http::StreamEvent;
        let gateway = start_gateway(ServerConfig::default(), GatewayConfig::default());
        let addr = gateway.local_addr();
        // Submit through the same bridge the HTTP handlers use, so the
        // FinishReason is directly observable.
        let (id, events) =
            gateway.handle().submit(Request::greedy(0, vec![1, 2, 3], 200)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut streamed = 0usize;
        while streamed < 2 {
            match events.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                Ok(StreamEvent::Token(_)) => streamed += 1,
                Ok(_) => {}
                Err(e) => panic!("stream stalled before cancel: {e:?}"),
            }
        }
        let (status, json) = oneshot(addr, "POST", &format!("/v1/cancel/{id}"), "");
        assert_eq!(status, 200);
        assert_eq!(json.get("accepted").and_then(Json::as_bool), Some(true));
        let reason = loop {
            match events.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                Ok(StreamEvent::Finished { reason, .. }) => break reason,
                Ok(_) => {}
                Err(e) => panic!("request never finished after cancel: {e:?}"),
            }
        };
        assert_eq!(reason, FinishReason::Cancelled);
        // Unparseable ids are a 400, unknown ids an accepted no-op.
        let (status, _) = oneshot(addr, "POST", "/v1/cancel/notanumber", "");
        assert_eq!(status, 400);
        let (status, json) = oneshot(addr, "POST", "/v1/cancel/999999", "");
        assert_eq!(status, 200);
        assert_eq!(json.get("accepted").and_then(Json::as_bool), Some(true));
        gateway.shutdown();
    });
}

#[test]
fn malformed_and_oversized_requests_get_4xx_not_hangs() {
    with_watchdog(120, || {
        let gcfg = GatewayConfig { max_max_new: 32, ..Default::default() };
        let gateway = start_gateway(ServerConfig::default(), gcfg);
        let addr = gateway.local_addr();
        for (body, why) in [
            ("not json at all", "unparseable body"),
            ("{\"max_new\": 4}", "missing prompt"),
            ("{\"prompt\": 7}", "prompt of the wrong type"),
            ("{\"prompt\": [70000]}", "token above u16::MAX"),
            ("{\"prompt\": [1.5]}", "fractional token"),
            ("{\"prompt\": [1], \"max_new\": -3}", "negative max_new"),
            ("{\"prompt\": [1], \"max_new\": 64}", "max_new above the gateway cap"),
            ("{\"prompt\": [1], \"temperature\": -1}", "negative temperature"),
            ("{\"prompt\": [1], \"stream\": \"yes\"}", "non-boolean stream"),
        ] {
            let (status, json) = oneshot(addr, "POST", "/v1/generate", body);
            assert_eq!(status, 400, "{why}: {json:?}");
            assert!(json.get("error").is_some(), "{why} must explain itself");
        }
        let (status, _) = oneshot(addr, "GET", "/no/such/path", "");
        assert_eq!(status, 404);
        let (status, _) = oneshot(addr, "GET", "/v1/generate", "");
        assert_eq!(status, 404, "generate is POST-only");
        let (status, _) = oneshot(addr, "BREW", "/v1/generate", "");
        assert_eq!(status, 405);
        // Declared body over the wire limit → 413 before any body byte.
        let mut stream = connect(addr);
        write!(
            stream,
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nConnection: close\r\nContent-Length: 9999999\r\n\r\n"
        )
        .unwrap();
        let (status, _) = read_response(&mut BufReader::new(stream));
        assert_eq!(status, 413);
        // Oversized head → 431. (24 KiB: over the 16 KiB head limit but
        // small enough to fit loopback socket buffers in one write.)
        let mut stream = connect(addr);
        write!(stream, "GET /healthz HTTP/1.1\r\nHost: x\r\nX-Big: {}\r\n\r\n", "a".repeat(24 << 10))
            .unwrap();
        let (status, _) = read_response(&mut BufReader::new(stream));
        assert_eq!(status, 431);
        // The gateway survives all of the above.
        let (status, json) = oneshot(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
        assert_eq!(json.get("ok").and_then(Json::as_bool), Some(true));
        gateway.shutdown();
    });
}

#[test]
fn keep_alive_serves_sequential_requests_and_metrics_report_work() {
    with_watchdog(120, || {
        let gateway = start_gateway(ServerConfig::default(), GatewayConfig::default());
        let addr = gateway.local_addr();
        // Three framed requests on one connection.
        let mut reader = BufReader::new(connect(addr));
        write_request(reader.get_mut(), "GET", "/healthz", "", false);
        let (status, json) = read_response(&mut reader);
        assert_eq!((status, json.get("ok").and_then(Json::as_bool)), (200, Some(true)));
        write_request(reader.get_mut(), "POST", "/v1/generate", "{\"prompt\": [5, 6], \"max_new\": 3}", false);
        let (status, json) = read_response(&mut reader);
        assert_eq!(status, 200);
        assert_eq!(frame_tokens(&json, "tokens").len(), 3);
        write_request(reader.get_mut(), "GET", "/v1/metrics", "", true);
        let (status, metrics) = read_response(&mut reader);
        assert_eq!(status, 200);
        assert_eq!(metrics.get("total_tokens").and_then(Json::as_usize), Some(3));
        assert!(metrics.get("weight_bytes").and_then(Json::as_usize).is_some_and(|b| b > 0));
        assert!(kv_pool_field(&metrics, "total_pages") > 0);
        gateway.shutdown();
    });
}

#[test]
fn overload_sheds_lowest_class_with_429_while_interactive_completes() {
    with_watchdog(180, || {
        // One slot, two queue seats: the fourth concurrent request must
        // push someone out, and strict class priority says who.
        let scfg = ServerConfig { max_batch: 1, seed: 0, queue_cap: 2, ..Default::default() };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        // A long Interactive stream pins the only slot while the queue
        // fills behind it.
        let mut a = open_sse(
            addr,
            "{\"prompt\": [1, 2, 3], \"max_new\": 1000, \"priority\": \"interactive\"}",
        );
        wait_first_token(&mut a);
        // B (best_effort) then C (batch) take the two queue seats; the
        // depth polls serialize their arrival order.
        let mut b = BufReader::new(connect(addr));
        write_request(
            b.get_mut(),
            "POST",
            "/v1/generate",
            "{\"prompt\": [4], \"max_new\": 2, \"priority\": \"best_effort\"}",
            true,
        );
        wait_metrics(addr, 60, "B to queue", |m| queue_depth(m) == 1);
        let mut c = BufReader::new(connect(addr));
        write_request(
            c.get_mut(),
            "POST",
            "/v1/generate",
            "{\"prompt\": [5], \"max_new\": 2, \"priority\": \"batch\"}",
            true,
        );
        wait_metrics(addr, 60, "C to queue", |m| queue_depth(m) == 2);
        // D (interactive) overflows the queue. The victim is the youngest
        // entry of the lowest waiting class strictly below it — B.
        let mut d = BufReader::new(connect(addr));
        write_request(
            d.get_mut(),
            "POST",
            "/v1/generate",
            "{\"prompt\": [6], \"max_new\": 2, \"priority\": \"interactive\"}",
            true,
        );
        let (status, headers, json) = read_response_headed(&mut b);
        assert_eq!(status, 429, "shed victim must get 429: {json:?}");
        assert_eq!(json.get("reason").and_then(Json::as_str), Some("shed"));
        assert_eq!(retry_after(&headers), Some("1"), "429 must carry Retry-After");
        // The pinned stream finishes untouched...
        let (streamed, done) = drain_sse(&mut a);
        assert_eq!(streamed.len(), 1000, "admitted work must be unaffected by shedding");
        assert_eq!(done.get("finish_reason").and_then(Json::as_str), Some("max_new"));
        // ...then the surviving queue entries are admitted and served.
        let (status, json) = read_response(&mut d);
        assert_eq!(status, 200, "queued Interactive request must be served: {json:?}");
        assert_eq!(frame_tokens(&json, "tokens").len(), 2);
        let (status, json) = read_response(&mut c);
        assert_eq!(status, 200, "queued Batch request must be served: {json:?}");
        let metrics = wait_metrics(addr, 60, "engine to quiesce", |m| {
            m.get("in_flight").and_then(Json::as_usize) == Some(0)
        });
        assert_eq!(metrics.get("shed").and_then(Json::as_usize), Some(1));
        assert_eq!(kv_pool_field(&metrics, "reserved_pages"), 0);
        gateway.shutdown();
    });
}

#[test]
fn queued_deadline_expiry_returns_503_and_releases_whole_reservation() {
    with_watchdog(180, || {
        let scfg = ServerConfig { max_batch: 1, seed: 0, queue_cap: 4, ..Default::default() };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        let mut a = open_sse(addr, "{\"prompt\": [1, 2, 3], \"max_new\": 1000}");
        wait_first_token(&mut a);
        // Queued behind the pinned slot with a 30 ms budget: the engine
        // must expire it at a tick, never admit it, and hold zero pages
        // for it the whole time.
        let mut e = BufReader::new(connect(addr));
        write_request(
            e.get_mut(),
            "POST",
            "/v1/generate",
            "{\"prompt\": [7, 8], \"max_new\": 4, \"priority\": \"batch\", \"deadline_ms\": 30}",
            true,
        );
        let (status, headers, json) = read_response_headed(&mut e);
        assert_eq!(status, 503, "expired-in-queue must be 503: {json:?}");
        assert_eq!(json.get("reason").and_then(Json::as_str), Some("deadline_exceeded"));
        assert_eq!(retry_after(&headers), Some("1"), "503 must carry Retry-After");
        // Hang up the pinned stream; the pool must come all the way back.
        drop(a);
        let metrics = wait_metrics(addr, 60, "pool to drain", |m| {
            m.get("in_flight").and_then(Json::as_usize) == Some(0)
                && kv_pool_field(m, "reserved_pages") == 0
        });
        assert_eq!(metrics.get("deadline_expired").and_then(Json::as_usize), Some(1));
        assert_eq!(kv_pool_field(&metrics, "in_use_pages"), 0);
        gateway.shutdown();
    });
}

#[test]
fn tenant_inflight_cap_rejects_with_tenant_cap_reason() {
    with_watchdog(180, || {
        let scfg = ServerConfig { max_batch: 2, seed: 0, ..Default::default() };
        let gcfg = GatewayConfig { tenant_max_inflight: 1, ..Default::default() };
        let gateway = start_gateway(scfg, gcfg);
        let addr = gateway.local_addr();
        let mut a =
            open_sse(addr, "{\"prompt\": [1, 2], \"max_new\": 1000, \"tenant\": \"acme\"}");
        wait_first_token(&mut a);
        // Same tenant, second concurrent request: the gateway-edge cap
        // fires before the engine ever sees it.
        let mut b = BufReader::new(connect(addr));
        write_request(
            b.get_mut(),
            "POST",
            "/v1/generate",
            "{\"prompt\": [3], \"max_new\": 2, \"tenant\": \"acme\"}",
            true,
        );
        let (status, headers, json) = read_response_headed(&mut b);
        assert_eq!(status, 429, "over-cap tenant must get 429: {json:?}");
        assert_eq!(json.get("reason").and_then(Json::as_str), Some("tenant_cap"));
        assert_eq!(retry_after(&headers), Some("1"));
        // Another tenant is unaffected — the cap is per-tenant, not global.
        let (status, json) =
            oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [4], \"max_new\": 2, \"tenant\": \"zeta\"}");
        assert_eq!(status, 200, "other tenants must pass: {json:?}");
        // Dropping acme's stream frees its seat (RAII permit, released
        // even on disconnect); retry until the cancel lands.
        drop(a);
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let (status, json) = oneshot(
                addr,
                "POST",
                "/v1/generate",
                "{\"prompt\": [5], \"max_new\": 2, \"tenant\": \"acme\"}",
            );
            if status == 200 {
                break;
            }
            assert_eq!(status, 429, "only the cap may reject here: {json:?}");
            assert!(Instant::now() < deadline, "acme's seat never freed after disconnect");
            std::thread::sleep(Duration::from_millis(5));
        }
        gateway.shutdown();
    });
}

#[test]
fn drain_endpoint_refuses_new_work_and_healthz_reports_draining() {
    with_watchdog(120, || {
        let gateway = start_gateway(ServerConfig::default(), GatewayConfig::default());
        let addr = gateway.local_addr();
        let (status, health) = oneshot(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
        assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
        let (status, _) = oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [1], \"max_new\": 2}");
        assert_eq!(status, 200);
        // Drain: the report shows the engine fully quiesced.
        let (status, report) = oneshot(addr, "POST", "/v1/drain", "");
        assert_eq!(status, 200);
        assert_eq!(report.get("draining").and_then(Json::as_bool), Some(true));
        let model = report
            .get("models")
            .and_then(|m| m.get("default"))
            .unwrap_or_else(|| panic!("drain report missing default model: {report:?}"));
        assert_eq!(model.get("in_flight").and_then(Json::as_usize), Some(0));
        assert_eq!(model.get("reserved_pages").and_then(Json::as_usize), Some(0));
        // New work is refused with a machine-readable reason + Retry-After.
        let mut g = BufReader::new(connect(addr));
        write_request(g.get_mut(), "POST", "/v1/generate", "{\"prompt\": [2], \"max_new\": 2}", true);
        let (status, headers, json) = read_response_headed(&mut g);
        assert_eq!(status, 503, "draining gateway must refuse generates: {json:?}");
        assert_eq!(json.get("reason").and_then(Json::as_str), Some("draining"));
        assert_eq!(retry_after(&headers), Some("1"));
        // Health flips to draining, and the status code takes the gateway
        // out of load-balancer rotation.
        let mut h = BufReader::new(connect(addr));
        write_request(h.get_mut(), "GET", "/healthz", "", true);
        let (status, health) = read_response(&mut h);
        assert_eq!(status, 503);
        assert_eq!(health.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(health.get("status").and_then(Json::as_str), Some("draining"));
        gateway.shutdown();
    });
}

#[test]
fn traffic_generator_overload_smoke_sheds_and_conserves_outcomes() {
    with_watchdog(180, || {
        // A deliberately tiny server (one slot, one queue seat) under a
        // burst arriving far faster than it can serve, with a disconnect
        // storm mixed in: some requests must shed, some must be served,
        // every request must be accounted for exactly once, and the KV
        // pool must come all the way back. This is the deterministic-seed
        // smoke run CI exercises in the test job.
        let scfg = ServerConfig { max_batch: 1, seed: 0, queue_cap: 1, ..Default::default() };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        let cfg = TrafficConfig {
            seed: 11,
            requests: 24,
            rate_rps: 2000.0,
            prompt_min: 4,
            prompt_max: 16,
            max_new_min: 48,
            max_new_max: 96,
            disconnect_frac: 0.25,
            ..Default::default()
        };
        let report = run_traffic(addr, &cfg);
        assert_eq!(report.sent(), cfg.requests, "open loop must send every planned request");
        for class in SloClass::ALL {
            let c = &report.per_class[class.index()];
            assert_eq!(
                c.ok + c.shed + c.expired + c.rejected + c.disconnected,
                c.sent,
                "{} outcomes must conserve: {c:?}",
                class.as_str()
            );
        }
        assert!(
            report.shed() > 0,
            "a 24-request burst against one slot + one seat must shed: {report:?}"
        );
        // At least one admitted request streamed tokens (it either ran to
        // completion or was one of the planned mid-stream hangups —
        // which request gets the slot first is scheduling-dependent).
        assert!(
            report.per_class.iter().map(|c| c.ok + c.disconnected).sum::<usize>() >= 1,
            "someone must still stream under overload: {report:?}"
        );
        assert!((0.0..=1.0).contains(&report.shed_rate));
        // Server-side ledger agrees and the pool came all the way back.
        let metrics = wait_metrics(addr, 60, "pool to drain", |m| {
            m.get("in_flight").and_then(Json::as_usize) == Some(0)
                && kv_pool_field(m, "reserved_pages") == 0
        });
        let engine_shed = metrics.get("shed").and_then(Json::as_usize).expect("shed counter");
        assert!(
            engine_shed >= report.shed(),
            "engine shed ledger ({engine_shed}) behind client view ({})",
            report.shed()
        );
        gateway.shutdown();
    });
}

#[test]
fn shared_prefix_traffic_hits_the_prefix_cache() {
    with_watchdog(180, || {
        // Every request leads with one of two 40-token preambles (spanning
        // one full 32-position KV page), so once the first request of each
        // preamble publishes its prompt pages, later admissions must reuse
        // them — visible as nonzero prefix_cache.hits in /v1/metrics.
        let scfg = ServerConfig { max_batch: 2, seed: 0, ..Default::default() };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        let cfg = TrafficConfig {
            seed: 7,
            requests: 12,
            rate_rps: 400.0,
            prompt_min: 4,
            prompt_max: 12,
            max_new_min: 4,
            max_new_max: 8,
            prefix_frac: 1.0,
            prefix_len: 40,
            n_prefixes: 2,
            ..Default::default()
        };
        let report = run_traffic(addr, &cfg);
        assert_eq!(report.sent(), cfg.requests, "open loop must send every planned request");
        let metrics = wait_metrics(addr, 60, "engine to quiesce", |m| {
            m.get("in_flight").and_then(Json::as_usize) == Some(0)
        });
        let pc = metrics
            .get("prefix_cache")
            .unwrap_or_else(|| panic!("metrics missing prefix_cache: {metrics:?}"));
        let hits = pc.get("hits").and_then(Json::as_usize).expect("prefix_cache.hits");
        let hit_tokens =
            pc.get("hit_tokens").and_then(Json::as_usize).expect("prefix_cache.hit_tokens");
        assert!(hits > 0, "shared 40-token preambles must hit the cache: {metrics:?}");
        assert!(
            hit_tokens >= hits * 32,
            "every hit here spans the full preamble page (hits={hits} hit_tokens={hit_tokens})"
        );
        assert!(pc.get("misses").and_then(Json::as_usize).is_some());
        assert!(pc.get("evictions").and_then(Json::as_usize).is_some());
        assert!(
            pc.get("cached_pages").and_then(Json::as_usize).is_some_and(|c| c > 0),
            "published prompt pages must sit in trie custody: {metrics:?}"
        );
        assert_eq!(
            pc.get("shared_pages").and_then(Json::as_usize),
            Some(0),
            "a quiesced engine pins nothing"
        );
        // The cache escape hatch: both spellings parse and still serve;
        // garbage is a 400, not a silent default.
        for body in [
            "{\"prompt\": [1, 2, 3], \"max_new\": 2, \"cache\": \"off\"}",
            "{\"prompt\": [1, 2, 3], \"max_new\": 2, \"cache\": false}",
            "{\"prompt\": [1, 2, 3], \"max_new\": 2, \"cache\": \"on\"}",
        ] {
            let (status, json) = oneshot(addr, "POST", "/v1/generate", body);
            assert_eq!(status, 200, "cache knob must not break serving: {json:?}");
        }
        let (status, json) =
            oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [1], \"cache\": 3}");
        assert_eq!(status, 400, "non-boolean cache value must be rejected: {json:?}");
        gateway.shutdown();
    });
}

/// Read one `Content-Length`-framed response without assuming a JSON body;
/// returns (status, content-type, raw body) — for the Prometheus text and
/// NDJSON endpoints.
fn read_raw_response(reader: &mut BufReader<TcpStream>) -> (u16, String, String) {
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {line:?}"));
    let mut content_length = 0usize;
    let mut content_type = String::new();
    loop {
        line.clear();
        reader.read_line(&mut line).expect("header line");
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            match name.trim().to_ascii_lowercase().as_str() {
                "content-length" => content_length = value.trim().parse().expect("length"),
                "content-type" => content_type = value.trim().to_string(),
                _ => {}
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("response body");
    (status, content_type, String::from_utf8(body).expect("utf8 body"))
}

fn oneshot_raw(addr: SocketAddr, method: &str, target: &str, body: &str) -> (u16, String, String) {
    let mut stream = connect(addr);
    write_request(&mut stream, method, target, body, true);
    read_raw_response(&mut BufReader::new(stream))
}

#[test]
fn trace_endpoint_returns_span_tree_that_round_trips() {
    with_watchdog(120, || {
        let scfg = ServerConfig { max_batch: 1, seed: 0, ..Default::default() };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        let (status, resp) =
            oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [5, 6, 7], \"max_new\": 4}");
        assert_eq!(status, 200);
        let id = resp.get("id").and_then(Json::as_usize).expect("response id");
        // The tree arrives as JSON text and re-parses through util::json
        // (read_response already round-trips); check its shape.
        let (status, tree) = oneshot(addr, "GET", &format!("/v1/trace/{id}"), "");
        assert_eq!(status, 200, "trace for a finished request: {tree:?}");
        assert_eq!(tree.get("id").and_then(Json::as_usize), Some(id));
        assert_eq!(tree.get("finish_reason").and_then(Json::as_str), Some("max_new"));
        let events = tree.get("events").and_then(Json::as_arr).expect("events");
        let kinds: Vec<&str> =
            events.iter().filter_map(|e| e.get("kind").and_then(Json::as_str)).collect();
        assert_eq!(kinds.first(), Some(&"submitted"));
        assert_eq!(kinds.last(), Some(&"finished"));
        assert!(kinds.contains(&"first_token"), "kinds: {kinds:?}");
        let spans = tree.get("spans").and_then(Json::as_arr).expect("spans array");
        let names: Vec<&str> =
            spans.iter().filter_map(|s| s.get("name").and_then(Json::as_str)).collect();
        for span in ["queued", "prefill", "decode"] {
            assert!(names.contains(&span), "missing span {span:?} in {names:?}");
        }
        // Unknown id → 404 with a JSON error; non-numeric id → 400.
        let (status, _) = oneshot(addr, "GET", "/v1/trace/999999", "");
        assert_eq!(status, 404);
        let (status, _) = oneshot(addr, "GET", "/v1/trace/not-a-number", "");
        assert_eq!(status, 400);
        gateway.shutdown();
    });
}

#[test]
fn debug_dump_streams_chrome_trace_ndjson() {
    with_watchdog(120, || {
        let scfg = ServerConfig { max_batch: 2, seed: 0, ..Default::default() };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        for _ in 0..2 {
            let (status, _) =
                oneshot(addr, "POST", "/v1/generate", "{\"prompt\": [1, 2], \"max_new\": 3}");
            assert_eq!(status, 200);
        }
        let (status, ctype, body) = oneshot_raw(addr, "POST", "/v1/debug/dump", "");
        assert_eq!(status, 200);
        assert_eq!(ctype, "application/x-ndjson");
        let lines: Vec<&str> = body.lines().collect();
        assert!(lines.len() >= 2, "two requests must leave events: {body:?}");
        for line in &lines {
            // Each NDJSON line is one Chrome-trace instant event and must
            // round-trip through util::json.
            let ev = Json::parse(line).unwrap_or_else(|e| panic!("bad line ({e}): {line}"));
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("i"));
            assert!(ev.get("name").and_then(Json::as_str).is_some());
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("pid").and_then(Json::as_usize).is_some());
            assert!(ev.get("tid").and_then(Json::as_usize).is_some());
        }
        gateway.shutdown();
    });
}

#[test]
fn prometheus_format_renders_families_and_leaves_json_untouched() {
    with_watchdog(120, || {
        let scfg = ServerConfig { max_batch: 1, seed: 0, ..Default::default() };
        let gateway = start_gateway(scfg, GatewayConfig::default());
        let addr = gateway.local_addr();
        let (status, _) = oneshot(
            addr,
            "POST",
            "/v1/generate",
            "{\"prompt\": [9, 8, 7], \"max_new\": 3, \"tenant\": \"acme\"}",
        );
        assert_eq!(status, 200);
        let (status, ctype, text) =
            oneshot_raw(addr, "GET", "/v1/metrics?format=prometheus", "");
        assert_eq!(status, 200);
        assert_eq!(ctype, "text/plain; version=0.0.4");
        for needle in [
            "# TYPE nanoquant_tokens_total counter",
            "nanoquant_tokens_total{model=\"default\"} 3",
            "# TYPE nanoquant_queue_wait_seconds histogram",
            "nanoquant_ttft_seconds_bucket{model=\"default\",class=\"interactive\",le=\"+Inf\"} 1",
            "nanoquant_tenant_requests_total{model=\"default\",tenant=\"acme\",outcome=\"admitted\"} 1",
            "nanoquant_tick_phase_seconds_count{model=\"default\",phase=\"sampling\"}",
            "nanoquant_kv_pool_pages{model=\"default\",state=\"total\"}",
            "nanoquant_up{model=\"default\"} 1",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in exposition:\n{text}");
        }
        // The JSON endpoint is untouched by the new format: same families
        // of data, legacy shape.
        let (status, json) = oneshot(addr, "GET", "/v1/metrics", "");
        assert_eq!(status, 200);
        assert_eq!(json.get("total_tokens").and_then(Json::as_usize), Some(3));
        assert!(json.get("queue_wait_hist").is_some());
        gateway.shutdown();
    });
}
