//! Integration: baseline orderings on a *trained* teacher — the qualitative
//! shape of paper Table 2's columns.

use nanoquant::data::{gen_corpus, sample_sequences, tokenize, CorpusKind};
use nanoquant::eval::perplexity;
use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::nn::trainer::train;
use nanoquant::quant::baselines::{
    arbllm::ArbLlmRc, billm::BiLlm, gptq::Gptq, hbllm::HbLlmCol, quantize_model_with, Rtn, Xnor,
};
use nanoquant::quant::pipeline::{calibrate_preconditioners, PipelineConfig};
use nanoquant::util::rng::Rng;
use std::collections::BTreeMap;

#[test]
fn baseline_ppl_ordering_on_trained_teacher() {
    let cfg = family_config("l2", "xs");
    let mut rng = Rng::new(0);
    let mut teacher = ModelParams::init(&cfg, &mut rng);
    let toks = tokenize(&gen_corpus(CorpusKind::SynthText, 300_000, 0));
    train(&mut teacher, &toks, 250, 8, 40, 3e-3, 1, false);
    let eval = tokenize(&gen_corpus(CorpusKind::SynthText, 50_000, 9));
    let seq = 40;

    let calib = sample_sequences(&toks, seq + 1, 8, &mut rng);
    let pre = calibrate_preconditioners(&teacher, &calib, seq, &PipelineConfig::default());
    let d_ins: BTreeMap<_, _> = pre.into_iter().map(|(id, (_o, i))| (id, i)).collect();

    let ppl = |quantizer: &dyn nanoquant::quant::baselines::WeightQuantizer| -> f64 {
        let res = quantize_model_with(quantizer, &teacher, &d_ins);
        perplexity(&res.params, &eval, seq, 8)
    };
    let teacher_ppl = perplexity(&teacher, &eval, seq, 8);
    let rtn = ppl(&Rtn);
    let xnor = ppl(&Xnor);
    let billm = ppl(&BiLlm::default());
    let arb = ppl(&ArbLlmRc::default());
    let hbllm = ppl(&HbLlmCol::default());
    let gptq = ppl(&Gptq::default());

    eprintln!(
        "teacher={teacher_ppl:.1} rtn={rtn:.1} xnor={xnor:.1} billm={billm:.1} \
         arb={arb:.1} hbllm={hbllm:.1} gptq={gptq:.1}"
    );
    // The paper's qualitative column shape:
    // naive 1-bit methods are far worse than structured binary PTQ…
    assert!(billm < rtn, "billm {billm} < rtn {rtn}");
    assert!(billm < xnor, "billm {billm} < xnor {xnor}");
    // …refined/structured variants improve on BiLLM…
    assert!(arb <= billm * 1.1, "arb {arb} vs billm {billm}");
    assert!(hbllm <= billm * 1.1, "hbllm {hbllm} vs billm {billm}");
    // …and everything structured stays within sight of the teacher.
    assert!(hbllm < teacher_ppl * 6.0, "hbllm {hbllm} vs teacher {teacher_ppl}");
    let _ = gptq;
}
