//! Integration: the full Algorithm-1 pipeline on a trained teacher must
//! land dramatically below naive binarization and near the teacher, and
//! the packed serving engine must agree with the materialized weights.

use nanoquant::data::{gen_corpus, sample_sequences, tokenize, CorpusKind};
use nanoquant::eval::perplexity;
use nanoquant::nn::decode::{decode_step, KvCache};
use nanoquant::nn::family_config;
use nanoquant::nn::model::{model_forward, LayerKind, ModelParams};
use nanoquant::nn::trainer::train;
use nanoquant::quant::{quantize, Engine, InitMethod, PipelineConfig};
use nanoquant::util::rng::Rng;

fn trained_teacher() -> (ModelParams, Vec<u16>) {
    let cfg = family_config("l2", "xs");
    let mut rng = Rng::new(0);
    let mut teacher = ModelParams::init(&cfg, &mut rng);
    let toks = tokenize(&gen_corpus(CorpusKind::SynthText, 300_000, 0));
    train(&mut teacher, &toks, 250, 8, 40, 3e-3, 1, false);
    (teacher, toks)
}

#[test]
fn full_pipeline_beats_naive_and_tracks_teacher() {
    let (teacher, toks) = trained_teacher();
    let mut rng = Rng::new(5);
    let seq = 40;
    let calib = sample_sequences(&toks, seq + 1, 16, &mut rng);
    let eval = tokenize(&gen_corpus(CorpusKind::SynthText, 60_000, 50));

    let pcfg = PipelineConfig { bpw: 1.5, ..Default::default() };
    let (qm, report) = quantize(&teacher, &calib, seq, &pcfg);

    let ppl_teacher = perplexity(&teacher, &eval, seq, 10);
    let ppl_quant = perplexity(&qm.params, &eval, seq, 10);
    // Naive sign baseline collapses on a trained model.
    let mut naive = teacher.clone();
    for b in naive.blocks.iter_mut() {
        for kind in LayerKind::ALL {
            let w = b.linear(kind);
            let alpha = w.abs_mean() as f32;
            *b.linear_mut(kind) = w.sign_pm1().scale(alpha);
        }
    }
    let ppl_naive = perplexity(&naive, &eval, seq, 10);

    assert!(
        ppl_quant < ppl_naive * 0.8,
        "quant {ppl_quant} must beat naive {ppl_naive} (teacher {ppl_teacher})"
    );
    assert!(
        ppl_quant < ppl_teacher * 4.0,
        "quant {ppl_quant} should stay in the teacher's ({ppl_teacher}) decade"
    );
    // The effective bitrate honors the request (rank rounding tolerance).
    assert!((report.effective_bpw - 1.5).abs() < 0.45, "bpw={}", report.effective_bpw);

    // Packed serving engine == materialized forward on the first logits.
    let dm = qm.to_decode_model(Engine::Packed);
    let mut cache = KvCache::new(&teacher.cfg);
    let logits_packed = decode_step(&dm, &mut cache, 42);
    let (logits_dense, _) = model_forward(&qm.params, &[42], 1, 1, false);
    for v in 0..teacher.cfg.vocab {
        let a = logits_packed[v];
        let b = logits_dense.at2(0, v);
        assert!((a - b).abs() < 2e-2 * (1.0 + b.abs()), "vocab {v}: {a} vs {b}");
    }
}

#[test]
fn sub_1bit_stays_functional() {
    let (teacher, toks) = trained_teacher();
    let mut rng = Rng::new(6);
    let seq = 40;
    let calib = sample_sequences(&toks, seq + 1, 12, &mut rng);
    let eval = tokenize(&gen_corpus(CorpusKind::SynthText, 50_000, 51));

    // Note: on the tiny xs model, sub-1-bit ranks are extremely small
    // (rank_for_bpw(64,64,0.8) = 9), so this is a stress test of the
    // structural path rather than a quality claim.
    let pcfg = PipelineConfig { bpw: 0.8, ..Default::default() };
    let (qm, report) = quantize(&teacher, &calib, seq, &pcfg);
    let ppl = perplexity(&qm.params, &eval, seq, 8);
    assert!(ppl.is_finite());
    // Sub-1-bit achieved (the structural claim PTQ baselines cannot make).
    assert!(report.effective_bpw < 1.0, "bpw={}", report.effective_bpw);
    // And the model is still far better than random (PPL 257).
    assert!(ppl < 150.0, "ppl={ppl}");
}

#[test]
fn init_method_ordering_matches_table5() {
    let (teacher, toks) = trained_teacher();
    let mut rng = Rng::new(7);
    let seq = 40;
    let calib = sample_sequences(&toks, seq + 1, 12, &mut rng);
    let eval = tokenize(&gen_corpus(CorpusKind::SynthText, 50_000, 52));
    let ppl_for = |init: InitMethod| -> f64 {
        let pcfg = PipelineConfig {
            bpw: 1.5,
            init,
            // isolate initialization: skip the tuning stages
            enable_mitigation: false,
            enable_refine: false,
            enable_recon: false,
            ..Default::default()
        };
        let (qm, _) = quantize(&teacher, &calib, seq, &pcfg);
        perplexity(&qm.params, &eval, seq, 8)
    };
    let ours = ppl_for(InitMethod::LbAdmm);
    let random = ppl_for(InitMethod::Random);
    assert!(ours < random * 0.8, "lb-admm {ours} vs random {random}");
}
