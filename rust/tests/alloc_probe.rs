//! Allocation probes for the serving hot path, backing two claims the
//! observability layer makes (`DESIGN.md` §Observability):
//!
//! 1. steady-state batched decode performs **zero** heap allocation — the
//!    arenas ([`BatchScratch`], the KV pages, the trace ring) are recycled,
//!    and the profiler's timing adds clock reads, never allocations;
//! 2. `Engine::step` allocates **identically** with observability on and
//!    off — the obs layer records into preallocated fixed-size storage.
//!
//! The counting `#[global_allocator]` is scoped to this test binary
//! (integration tests are separate crates), and counts every thread, so
//! the tests serialize through one mutex to keep measurements clean.

use nanoquant::nn::decode::{decode_batch_into, dense_decode_model, BatchScratch, KvCache};
use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::serve::{Engine, Request, ServerConfig};
use nanoquant::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// System allocator with an allocation-event counter (alloc, alloc_zeroed
/// and realloc count; dealloc is free-ing, not allocating).
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The harness runs tests on parallel threads and the counter is global:
/// each test holds this for its whole body so measurements never overlap.
static SERIAL: Mutex<()> = Mutex::new(());

fn alloc_events() -> u64 {
    ALLOC_EVENTS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_decode_is_allocation_free_with_and_without_timing() {
    let _guard = SERIAL.lock().unwrap();
    let mcfg = family_config("l2", "xs");
    let mut rng = Rng::new(0);
    let params = ModelParams::init(&mcfg, &mut rng);
    let model = dense_decode_model(&params);
    let mut caches = vec![KvCache::new(&mcfg)];
    let mut scratch = BatchScratch::new(&mcfg, 1);

    // Warmup: first steps allocate the cache's first KV page; afterwards
    // every step up to the 32-token page boundary reuses it. Width 1 also
    // keeps the attention fan-out on the serial path, so the measurement
    // covers the whole call, threadpool included.
    for _ in 0..4 {
        decode_batch_into(&model, &mut caches, &[7], &mut scratch);
    }

    let before = alloc_events();
    for _ in 0..8 {
        decode_batch_into(&model, &mut caches, &[7], &mut scratch);
    }
    assert_eq!(alloc_events() - before, 0, "steady-state decode must not allocate");

    // Profiler timing on: clock reads and f64 accumulation only — still
    // exactly zero allocations.
    scratch.timing = true;
    let before = alloc_events();
    for _ in 0..8 {
        decode_batch_into(&model, &mut caches, &[7], &mut scratch);
    }
    assert_eq!(alloc_events() - before, 0, "phase timing must not allocate");
    assert!(scratch.gemm_s >= 0.0 && scratch.attn_s >= 0.0);
}

/// Drive a fresh engine to a mid-decode steady state and count the
/// allocation events of the next few ticks.
fn steady_step_allocs(obs: bool) -> u64 {
    let mcfg = family_config("l2", "xs");
    let mut rng = Rng::new(0);
    let params = ModelParams::init(&mcfg, &mut rng);
    let mut engine = Engine::new(
        dense_decode_model(&params),
        ServerConfig { max_batch: 1, obs, ..Default::default() },
    );
    engine.submit(Request::greedy(0, vec![3, 4, 5, 6], 40));
    for _ in 0..4 {
        engine.step(); // admission + prefill + the first decode ticks
    }
    let before = alloc_events();
    for _ in 0..5 {
        engine.step();
    }
    alloc_events() - before
}

#[test]
fn engine_step_allocates_identically_with_obs_on_and_off() {
    let _guard = SERIAL.lock().unwrap();
    // step() itself allocates (the per-tick event Vec), so the decode
    // path's bar is parity, not zero: the trace ring, histograms and
    // profiler arena are preallocated, so turning obs on must not add a
    // single allocation event to an identical workload.
    let with_obs = steady_step_allocs(true);
    let without = steady_step_allocs(false);
    assert_eq!(
        with_obs, without,
        "obs on allocated {with_obs} events vs {without} with obs off"
    );
}
