//! Integration: the quantization-run observer's on-disk artifacts — the
//! `--events` NDJSON stream and `QUANT_REPORT.json` — written through the
//! real file sink and parsed back, with lifecycle count conservation.

use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::obs::{EventSink, RunObserver, Watchdog};
use nanoquant::quant::{quantize_observed, AdmmConfig, PipelineConfig};
use nanoquant::util::json::{parse_ndjson, write_json, Json};
use nanoquant::util::rng::Rng;

fn tiny() -> (ModelParams, Vec<Vec<u16>>, usize, PipelineConfig) {
    let cfgm = family_config("l2", "xs");
    let mut rng = Rng::new(11);
    let teacher = ModelParams::init(&cfgm, &mut rng);
    let calib: Vec<Vec<u16>> =
        (0..4).map(|i| (0..17).map(|j| ((i * 29 + j * 5) % 250) as u16).collect()).collect();
    let pcfg = PipelineConfig {
        bpw: 2.0,
        t_pre: 3,
        t_post: 4,
        t_glob: 3,
        stats_seqs: 2,
        admm: AdmmConfig { iters: 4, ..Default::default() },
        ..Default::default()
    };
    (teacher, calib, 16, pcfg)
}

#[test]
fn ndjson_file_sink_and_report_roundtrip() {
    let dir = std::env::temp_dir().join(format!("nanoquant-obs-{}", std::process::id()));
    let events_path = dir.join("run.ndjson");
    let report_path = dir.join("QUANT_REPORT.json");
    let (teacher, calib, seq, pcfg) = tiny();

    let sink = EventSink::file(events_path.to_str().unwrap()).expect("file sink opens");
    let mut obs = RunObserver::new(Some(sink), false, Watchdog::Warn);
    let (_qm, report) =
        quantize_observed(&teacher, &calib, seq, &pcfg, Some(&mut obs)).unwrap();
    drop(obs); // flush the BufWriter (run_done already flushed; drop is belt+braces)

    // ---- NDJSON stream: parses line-by-line, lifecycle counts conserve ----
    let text = std::fs::read_to_string(&events_path).unwrap();
    let events = parse_ndjson(&text).expect("every event line parses");
    let count = |ev: &str| {
        events.iter().filter(|e| e.get("ev").and_then(Json::as_str) == Some(ev)).count()
    };
    assert_eq!(count("run_started"), 1);
    assert_eq!(count("run_done"), 1);
    assert_eq!(count("phase_started"), count("phase_done"));
    assert_eq!(count("block_started"), count("block_done"));
    assert_eq!(count("block_done"), teacher.cfg.n_layers);
    // `t` is monotone non-decreasing across the stream.
    let ts: Vec<f64> = events.iter().map(|e| e.get("t").unwrap().as_f64().unwrap()).collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "event timestamps went backwards");

    // ---- QUANT_REPORT.json: write -> parse roundtrip through disk ----
    let doc = report.to_json();
    write_json(report_path.to_str().unwrap(), &doc).unwrap();
    let back = Json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
    assert_eq!(back, doc, "report must roundtrip bit-for-bit through disk");
    assert_eq!(back.get("blocks").unwrap().as_arr().unwrap().len(), teacher.cfg.n_layers);
    assert!(back.get("achieved").unwrap().get("bpw").unwrap().as_f64().unwrap() > 0.0);
    assert!(back.get("wall_seconds").unwrap().as_f64().unwrap() >= 0.0);
    // Phase histograms survive serialization with count conservation.
    let hists = back.get("phase_hists").unwrap().as_arr().unwrap();
    assert!(!hists.is_empty());
    let names: Vec<&str> =
        hists.iter().map(|h| h.get("name").unwrap().as_str().unwrap()).collect();
    for phase in ["phase:calibration", "phase:block_recon", "phase:global_recon"] {
        assert!(names.contains(&phase), "missing {phase} in {names:?}");
    }
    for h in hists {
        let n = h.get("count").unwrap().as_f64().unwrap();
        let bucket_sum: f64 = h
            .get("buckets")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.as_f64().unwrap())
            .sum();
        assert_eq!(n, bucket_sum, "histogram {:?} lost samples", h.get("name"));
    }

    let _ = std::fs::remove_dir_all(&dir);
}
