//! Sweep the accuracy-per-bit frontier: quantize one teacher across a range
//! of BPW targets and print the (bits, size, perplexity) curve — a
//! minimal version of the paper's Fig. 6 Pareto analysis.
//!
//!     cargo run --release --example sweep_bpw [-- --family l2 --size xs]

use nanoquant::data::{gen_corpus, sample_sequences, tokenize, CorpusKind};
use nanoquant::eval::perplexity;
use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::nn::trainer::train;
use nanoquant::quant::{quantize, PipelineConfig};
use nanoquant::util::cli::Args;
use nanoquant::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let family = args.get_or("family", "l2");
    let size = args.get_or("size", "xs");
    let cfg = family_config(family, size);
    let mut rng = Rng::new(1);
    let mut teacher = ModelParams::init(&cfg, &mut rng);
    let corpus = tokenize(&gen_corpus(CorpusKind::SynthText, 500_000, 3));
    eprintln!("training {}…", cfg.name);
    train(&mut teacher, &corpus, 300, 6, 48, 3e-3, 4, false);

    let seq = 48;
    let calib = sample_sequences(&corpus, seq + 1, 16, &mut rng);
    let eval = tokenize(&gen_corpus(CorpusKind::SynthText, 80_000, 5));
    let ppl_fp = perplexity(&teacher, &eval, seq, 10);
    println!("{:<8} {:>8} {:>10} {:>8}", "bpw", "achieved", "size (KB)", "ppl");
    println!("{:<8} {:>8} {:>10} {:>8.2}", "16.0", "16.00", "-", ppl_fp);
    for bpw in [3.0, 2.0, 1.5, 1.0, 0.8, 0.55] {
        let pcfg = PipelineConfig { bpw, ..Default::default() };
        let (qm, report) = quantize(&teacher, &calib, seq, &pcfg);
        let ppl = perplexity(&qm.params, &eval, seq, 10);
        println!(
            "{:<8} {:>8.2} {:>10.0} {:>8.2}",
            bpw,
            report.effective_bpw,
            report.effective_bytes as f64 / 1e3,
            ppl
        );
    }
}
