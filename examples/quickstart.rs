//! Quickstart: quantize a small trained model to 1 bit with NanoQuant and
//! verify the quality/size trade against naive binarization.
//!
//!     cargo run --release --example quickstart

use nanoquant::data::{gen_corpus, sample_sequences, tokenize, CorpusKind};
use nanoquant::eval::perplexity;
use nanoquant::nn::family_config;
use nanoquant::nn::model::{LayerKind, ModelParams};
use nanoquant::nn::trainer::train;
use nanoquant::quant::{quantize, PipelineConfig};
use nanoquant::util::rng::Rng;

fn main() {
    // 1. A small teacher, trained briefly on the synthetic corpus.
    let cfg = family_config("l2", "xs");
    let mut rng = Rng::new(0);
    let mut teacher = ModelParams::init(&cfg, &mut rng);
    let corpus = tokenize(&gen_corpus(CorpusKind::SynthText, 400_000, 0));
    println!("training a {} teacher ({} params)…", cfg.name, nanoquant::nn::param_count(&cfg));
    train(&mut teacher, &corpus, 300, 8, 48, 3e-3, 1, false);

    // 2. Calibration set: 24 sequences (the paper uses 128 x 2048 tokens).
    let seq = 48;
    let calib = sample_sequences(&corpus, seq + 1, 24, &mut rng);

    // 3. Quantize to an effective 1.0 bits per weight.
    let pcfg = PipelineConfig { bpw: 1.0, verbose: true, ..Default::default() };
    let (qm, report) = quantize(&teacher, &calib, seq, &pcfg);
    println!(
        "quantized: {:.3} effective BPW, {:.2} MB, {:.1}s wall",
        report.effective_bpw,
        report.effective_bytes as f64 / 1e6,
        report.wall_seconds
    );

    // 4. Compare perplexity: teacher vs NanoQuant vs naive sign binarization.
    let eval = tokenize(&gen_corpus(CorpusKind::SynthText, 60_000, 9));
    let ppl_teacher = perplexity(&teacher, &eval, seq, 10);
    let ppl_quant = perplexity(&qm.params, &eval, seq, 10);
    let mut naive = teacher.clone();
    for b in naive.blocks.iter_mut() {
        for kind in LayerKind::ALL {
            let w = b.linear(kind);
            let alpha = w.abs_mean() as f32;
            *b.linear_mut(kind) = w.sign_pm1().scale(alpha);
        }
    }
    let ppl_naive = perplexity(&naive, &eval, seq, 10);
    println!("perplexity:  teacher {ppl_teacher:.2}  |  NanoQuant@1bit {ppl_quant:.2}  |  naive sign {ppl_naive:.2}");
    assert!(ppl_quant < ppl_naive, "NanoQuant must beat naive binarization");
    println!("quickstart OK");
}
