//! Serving demo: the event-driven engine — token streaming, mid-flight
//! submission, deferral under a tight KV budget, and cancellation — on the
//! NanoQuant packed kernels, then an offline dense-vs-packed throughput
//! comparison and the device cost model's view of the paper's consumer-GPU
//! headline claim.
//!
//!     cargo run --release --example serving

use nanoquant::nn::decode::dense_decode_model;
use nanoquant::nn::family_config;
use nanoquant::nn::model::{LayerKind, ModelParams};
use nanoquant::nn::LayerId;
use nanoquant::quant::{rank_for_bpw, Engine, LatentFactors, QuantModel};
use nanoquant::serve::device::{estimate_decode, RTX_3050};
use nanoquant::serve::{Engine as ServeEngine, Event, Request, Server, ServerConfig};
use nanoquant::tensor::Tensor;
use nanoquant::util::rng::Rng;

fn main() {
    let cfg = family_config("l2", "s");
    let mut rng = Rng::new(3);
    let params = ModelParams::init(&cfg, &mut rng);

    // A quantized twin (random factors — engine mechanics demo).
    let mut qm = QuantModel::from_teacher(&params);
    for bi in 0..cfg.n_layers {
        for kind in LayerKind::ALL {
            let w = params.blocks[bi].linear(kind);
            let (n, m) = (w.rows(), w.cols());
            let r = rank_for_bpw(n, m, 1.0).min(n).min(m);
            qm.set_layer(
                LayerId { block: bi, kind },
                LatentFactors {
                    u: Tensor::randn(&[n, r], 1.0, &mut rng),
                    v: Tensor::randn(&[m, r], 1.0, &mut rng),
                    s1: (0..n).map(|_| rng.uniform_in(0.005, 0.02)).collect(),
                    s2: (0..m).map(|_| rng.uniform_in(0.5, 1.5)).collect(),
                },
            );
        }
        qm.freeze_block(bi);
    }

    // ---- 1. The event loop: four slots but only a 4-page KV budget, three
    // 2-page requests (the third defers on pages, not slots), one more
    // submitted mid-flight, and a cancellation once request 1 is decoding.
    // Tokens stream per tick; the timeline below is the whole serve-side
    // API surface.
    println!("== event-driven engine (NanoQuant packed) ==");
    let mut engine = ServeEngine::new(
        qm.to_decode_model(Engine::Packed),
        ServerConfig { max_batch: 4, kv_pages: Some(4), seed: 0, ..Default::default() },
    );
    let mk_prompt = |i: u64| -> Vec<u16> {
        (0..40).map(|j| ((i as usize * 31 + j * 7) % 250) as u16).collect()
    };
    for i in 0..3 {
        engine.submit(Request::greedy(i, mk_prompt(i), 12));
    }
    let mut step = 0usize;
    let mut streamed = vec![0usize; 8];
    let mut late_submitted = false;
    let mut cancel_sent = false;
    while !engine.is_idle() {
        for ev in engine.step() {
            match ev {
                Event::Started { id } => println!("  tick {step:>3}  [{id}] started"),
                Event::Deferred { id } => {
                    println!("  tick {step:>3}  [{id}] deferred (KV pool full; stays queued)")
                }
                Event::Token { id, token } => {
                    streamed[id as usize] += 1;
                    if streamed[id as usize] == 1 {
                        println!("  tick {step:>3}  [{id}] first token {token} (TTFT observable)");
                    }
                }
                Event::Finished { response, reason } => println!(
                    "  tick {step:>3}  [{}] finished {reason:?}: {} tokens, queue {:.1} ms, ttft {:.1} ms",
                    response.id,
                    response.tokens.len(),
                    response.queue_s * 1e3,
                    response.ttft_s * 1e3,
                ),
            }
        }
        step += 1;
        if !late_submitted && step == 4 {
            late_submitted = true;
            println!("  tick {step:>3}  ---- submitting request 3 mid-flight ----");
            engine.submit(Request::greedy(3, mk_prompt(3), 12));
        }
        if !cancel_sent && streamed[1] >= 2 {
            cancel_sent = true;
            println!("  tick {step:>3}  ---- cancelling request 1 mid-decode ----");
            engine.cancel(1);
        }
    }
    let m = engine.snapshot();
    println!(
        "  engine: {:.1} tok/s, {} deferrals, {} cancellations, peak KV {:.0} KB\n",
        m.tokens_per_s,
        m.admission_deferrals,
        m.cancellations,
        m.peak_kv_bytes as f64 / 1e3,
    );

    // ---- 2. Offline batch comparison through the Server compatibility
    // loop (same engine underneath).
    let mk_requests = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                let plen = 4 + (i * 5) % 20;
                Request::greedy(
                    i as u64,
                    (0..plen).map(|j| ((i * 31 + j * 7) % 250) as u16).collect(),
                    16,
                )
            })
            .collect()
    };

    for (label, dm) in [
        ("dense f32", dense_decode_model(&params)),
        ("NanoQuant packed", qm.to_decode_model(Engine::Packed)),
    ] {
        let mut server =
            Server::new(dm, ServerConfig { max_batch: 4, seed: 0, ..Default::default() });
        let resps = server.run(mk_requests());
        let mean_ttft: f64 = resps.iter().map(|r| r.ttft_s).sum::<f64>() / resps.len() as f64;
        println!(
            "{label:<18} {:.1} tok/s  mean ttft {:.1} ms  weights {:.2} MB  peak slots {}",
            server.metrics.tokens_per_s,
            mean_ttft * 1e3,
            server.metrics.weight_bytes as f64 / 1e6,
            server.metrics.peak_active_slots
        );
    }

    // What this means on the paper's consumer GPU (device cost model):
    println!("\nRTX 3050 roofline for the published Llama-2-70B shapes:");
    for (label, bytes) in [("BF16", 137_950_000_000usize), ("NanoQuant@0.55", 5_750_000_000)] {
        let est = estimate_decode(&RTX_3050, bytes, 120_000_000, 100_000_000);
        println!(
            "  {label:<16} fits={:<5} {:.1} tok/s  {:.1} GB  {:.3} J/token",
            est.fits, est.tokens_per_s, est.peak_mem_gb, est.energy_per_token_j
        );
    }
}
