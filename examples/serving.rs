//! Serving demo: continuous batching over mixed-length requests, comparing
//! the dense engine against the NanoQuant packed engine, plus the device
//! cost model's view of the paper's consumer-GPU headline claim.
//!
//!     cargo run --release --example serving

use nanoquant::nn::decode::dense_decode_model;
use nanoquant::nn::family_config;
use nanoquant::nn::model::{LayerKind, ModelParams};
use nanoquant::nn::LayerId;
use nanoquant::quant::{rank_for_bpw, Engine, LatentFactors, QuantModel};
use nanoquant::serve::device::{estimate_decode, RTX_3050};
use nanoquant::serve::{Request, Server, ServerConfig};
use nanoquant::tensor::Tensor;
use nanoquant::util::rng::Rng;

fn main() {
    let cfg = family_config("l2", "s");
    let mut rng = Rng::new(3);
    let params = ModelParams::init(&cfg, &mut rng);

    // A quantized twin (random factors — engine mechanics demo).
    let mut qm = QuantModel::from_teacher(&params);
    for bi in 0..cfg.n_layers {
        for kind in LayerKind::ALL {
            let w = params.blocks[bi].linear(kind);
            let (n, m) = (w.rows(), w.cols());
            let r = rank_for_bpw(n, m, 1.0).min(n).min(m);
            qm.set_layer(
                LayerId { block: bi, kind },
                LatentFactors {
                    u: Tensor::randn(&[n, r], 1.0, &mut rng),
                    v: Tensor::randn(&[m, r], 1.0, &mut rng),
                    s1: (0..n).map(|_| rng.uniform_in(0.005, 0.02)).collect(),
                    s2: (0..m).map(|_| rng.uniform_in(0.5, 1.5)).collect(),
                },
            );
        }
        qm.freeze_block(bi);
    }

    let mk_requests = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                let plen = 4 + (i * 5) % 20;
                Request::greedy(
                    i as u64,
                    (0..plen).map(|j| ((i * 31 + j * 7) % 250) as u16).collect(),
                    16,
                )
            })
            .collect()
    };

    for (label, dm) in [
        ("dense f32", dense_decode_model(&params)),
        ("NanoQuant packed", qm.to_decode_model(Engine::Packed)),
    ] {
        let mut server =
            Server::new(dm, ServerConfig { max_batch: 4, seed: 0, ..Default::default() });
        let resps = server.run(mk_requests());
        let mean_ttft: f64 = resps.iter().map(|r| r.ttft_s).sum::<f64>() / resps.len() as f64;
        println!(
            "{label:<18} {:.1} tok/s  mean ttft {:.1} ms  weights {:.2} MB  peak slots {}",
            server.metrics.tokens_per_s,
            mean_ttft * 1e3,
            server.metrics.weight_bytes as f64 / 1e6,
            server.metrics.peak_active_slots
        );
    }

    // What this means on the paper's consumer GPU (device cost model):
    println!("\nRTX 3050 roofline for the published Llama-2-70B shapes:");
    for (label, bytes) in [("BF16", 137_950_000_000usize), ("NanoQuant@0.55", 5_750_000_000)] {
        let est = estimate_decode(&RTX_3050, bytes, 120_000_000, 100_000_000);
        println!(
            "  {label:<16} fits={:<5} {:.1} tok/s  {:.1} GB  {:.3} J/token",
            est.fits, est.tokens_per_s, est.peak_mem_gb, est.energy_per_token_j
        );
    }
}
