//! HTTP gateway demo: starts the std-only SSE gateway on an ephemeral
//! loopback port, then acts as its own HTTP client — liveness check, a
//! full-response generation, a live token stream, a mid-stream disconnect
//! (watch the engine cancel and the KV pool refill), and the metrics view.
//!
//!     cargo run --release --example http_gateway

use nanoquant::nn::decode::dense_decode_model;
use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::serve::http::{Gateway, GatewayConfig};
use nanoquant::serve::{Engine, ServerConfig};
use nanoquant::util::json::Json;
use nanoquant::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

fn main() {
    let cfg = family_config("l2", "s");
    let mut rng = Rng::new(3);
    let params = ModelParams::init(&cfg, &mut rng);
    let engine = Engine::new(
        dense_decode_model(&params),
        ServerConfig { max_batch: 4, kv_pages: Some(8), seed: 0, ..Default::default() },
    );
    let gateway = Gateway::start(
        engine,
        GatewayConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .expect("bind loopback gateway");
    let addr = gateway.local_addr();
    println!("gateway up on http://{addr}\n");

    // ---- 1. Liveness.
    let (status, body) = request(addr, "GET", "/healthz", "");
    println!("GET /healthz            -> {status} {body}");

    // ---- 2. Full-response generation.
    let (status, body) =
        request(addr, "POST", "/v1/generate", "{\"prompt\": \"the robin is a kind of\", \"max_new\": 12}");
    println!("POST /v1/generate       -> {status}");
    let resp = Json::parse(&body).expect("response JSON");
    println!(
        "  finish={} ttft={:.1}ms text={:?}",
        resp.get("finish_reason").and_then(Json::as_str).unwrap_or("?"),
        resp.get("ttft_s").and_then(Json::as_f64).unwrap_or(0.0) * 1e3,
        resp.get("text").and_then(Json::as_str).unwrap_or(""),
    );

    // ---- 3. SSE stream: tokens arrive the tick they are sampled.
    println!("POST /v1/generate?stream=1");
    let mut reader = open_sse(addr, "{\"prompt\": \"the robin is a kind of\", \"max_new\": 10}");
    let t0 = Instant::now();
    while let Some(frame) = next_frame(&mut reader) {
        if frame.get("done").and_then(Json::as_bool) == Some(true) {
            println!(
                "  done: finish={} wire-wall={:.1}ms",
                frame.get("finish_reason").and_then(Json::as_str).unwrap_or("?"),
                t0.elapsed().as_secs_f64() * 1e3,
            );
            break;
        }
        if let Some(tok) = frame.get("token").and_then(Json::as_usize) {
            println!("  +{:>6.1}ms token {tok}", t0.elapsed().as_secs_f64() * 1e3);
        }
    }

    // ---- 4. Disconnect containment: drop a stream mid-flight and watch
    // the cancel land and the page reservation come back.
    println!("\nmid-stream disconnect:");
    let mut reader = open_sse(addr, "{\"prompt\": \"the robin is a kind of\", \"max_new\": 400}");
    let mut seen = 0usize;
    while seen < 3 {
        let frame = next_frame(&mut reader).expect("stream ended early");
        if frame.get("token").is_some() {
            seen += 1;
        }
    }
    drop(reader); // hang up without reading the rest
    println!("  dropped the connection after 3 tokens");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (_, body) = request(addr, "GET", "/v1/metrics", "");
        let m = Json::parse(&body).expect("metrics JSON");
        let cancellations = m.get("cancellations").and_then(Json::as_usize).unwrap_or(0);
        if cancellations >= 1 {
            let pool = m.get("kv_pool").expect("kv_pool");
            println!(
                "  engine cancelled it: cancellations={cancellations} reserved_pages={} in_use_pages={}",
                pool.get("reserved_pages").and_then(Json::as_usize).unwrap_or(9999),
                pool.get("in_use_pages").and_then(Json::as_usize).unwrap_or(9999),
            );
            break;
        }
        assert!(Instant::now() < deadline, "cancel never landed");
        std::thread::sleep(Duration::from_millis(20));
    }

    // ---- 5. Lifetime metrics, then a clean shutdown.
    let (_, body) = request(addr, "GET", "/v1/metrics", "");
    let m = Json::parse(&body).expect("metrics JSON");
    println!(
        "\nmetrics: total_tokens={} tokens_per_s={:.1} peak_kv_bytes={}",
        m.get("total_tokens").and_then(Json::as_usize).unwrap_or(0),
        m.get("tokens_per_s").and_then(Json::as_f64).unwrap_or(0.0),
        m.get("peak_kv_bytes").and_then(Json::as_usize).unwrap_or(0),
    );
    gateway.shutdown();
    println!("gateway shut down cleanly");
}

fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: demo\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("write request");
    let mut raw = String::new();
    BufReader::new(stream).read_to_string(&mut raw).expect("read response");
    let status = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let body_at = raw.find("\r\n\r\n").expect("head/body split") + 4;
    (status, raw[body_at..].to_string())
}

fn open_sse(addr: SocketAddr, body: &str) -> BufReader<TcpStream> {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(
        stream,
        "POST /v1/generate?stream=1 HTTP/1.1\r\nHost: demo\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("write request");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    assert!(line.starts_with("HTTP/1.1 200"), "{line}");
    loop {
        line.clear();
        reader.read_line(&mut line).expect("header");
        if line.trim_end().is_empty() {
            return reader;
        }
    }
}

fn next_frame(reader: &mut BufReader<TcpStream>) -> Option<Json> {
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).ok()? == 0 {
            return None;
        }
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            continue;
        }
        return Json::parse(trimmed.strip_prefix("data: ")?).ok();
    }
}
