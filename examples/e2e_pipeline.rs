//! End-to-end driver (the full-system validation run recorded in
//! EXPERIMENTS.md): trains a teacher transformer for a few hundred steps on
//! the synthetic corpus (loss curve logged), quantizes it with the complete
//! NanoQuant pipeline at 1.0 / 0.55 bits, evaluates perplexity + zero-shot,
//! and serves batched requests through the packed-kernel engine, reporting
//! latency and throughput — all three layers composing.
//!
//!     cargo run --release --example e2e_pipeline

use nanoquant::data::{gen_corpus, sample_sequences, tokenize, CorpusKind};
use nanoquant::eval::{perplexity, zero_shot_suite};
use nanoquant::nn::family_config;
use nanoquant::nn::model::ModelParams;
use nanoquant::nn::trainer::train;
use nanoquant::quant::{quantize, Engine, PipelineConfig};
use nanoquant::serve::{Request, Server, ServerConfig};
use nanoquant::util::rng::Rng;

fn main() {
    let t0 = std::time::Instant::now();

    // ---- 1. Train the teacher (a few hundred steps, loss curve logged) ----
    let cfg = family_config("l2", "s");
    let mut rng = Rng::new(7);
    let mut teacher = ModelParams::init(&cfg, &mut rng);
    let corpus = tokenize(&gen_corpus(CorpusKind::SynthText, 1_200_000, 7));
    println!(
        "[1/4] training {} ({} params) for 400 steps…",
        cfg.name,
        nanoquant::nn::param_count(&cfg)
    );
    let report = train(&mut teacher, &corpus, 400, 6, 48, 3e-3, 8, true);
    println!(
        "      loss: {:.3} -> {:.3} over {} tokens",
        report.losses[0],
        report.losses.last().unwrap(),
        report.tokens_seen
    );

    // ---- 2. Quantize with the full pipeline ----
    let seq = 48;
    let calib = sample_sequences(&corpus, seq + 1, 24, &mut rng);
    let eval = tokenize(&gen_corpus(CorpusKind::SynthText, 100_000, 99));
    let ppl_teacher = perplexity(&teacher, &eval, seq, 12);
    let (_, zs_teacher) = zero_shot_suite(&teacher, 30, 0);
    println!("[2/4] teacher: ppl={ppl_teacher:.2} zero-shot={zs_teacher:.1}%");

    for bpw in [1.0, 0.55] {
        let pcfg = PipelineConfig { bpw, verbose: false, ..Default::default() };
        let (qm, qreport) = quantize(&teacher, &calib, seq, &pcfg);
        let ppl = perplexity(&qm.params, &eval, seq, 12);
        let (_, zs) = zero_shot_suite(&qm.params, 30, 0);
        println!(
            "[3/4] NanoQuant@{bpw}: ppl={ppl:.2} zero-shot={zs:.1}% size={:.2}MB ({:.1}x smaller) wall={:.0}s",
            qreport.effective_bytes as f64 / 1e6,
            (nanoquant::nn::param_count(&cfg) * 2) as f64 / qreport.effective_bytes as f64,
            qreport.wall_seconds
        );

        // ---- 3. Serve batched requests on the packed engine ----
        let mut server = Server::new(
            qm.to_decode_model(Engine::Packed),
            ServerConfig { max_batch: 4, seed: 0, ..Default::default() },
        );
        let prompts = [
            "the robin is a kind of",
            "you can use a hammer to",
            "when the rain falls,",
            "is the salmon a fish?",
            "the oak lives in the",
            "the wolf is",
        ];
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Request::new(i as u64, nanoquant::data::tokenize(p))
                    .max_new(24)
                    .temperature(0.7)
                    .top_k(20)
            })
            .collect();
        let resps = server.run(reqs);
        for r in resps.iter().take(3) {
            println!("      [{}] '{}{}'", r.id, prompts[r.id as usize], r.text.trim_end());
        }
        println!(
            "[4/4] served {} tokens @ {:.1} tok/s (batch {}, weights {:.2}MB, peak kv {:.2}MB)",
            server.metrics.total_tokens,
            server.metrics.tokens_per_s,
            server.metrics.peak_active_slots,
            server.metrics.weight_bytes as f64 / 1e6,
            server.metrics.peak_kv_bytes as f64 / 1e6,
        );
    }
    println!("e2e pipeline done in {:.0}s", t0.elapsed().as_secs_f64());
}
