"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes/ranks; every case asserts allclose between the
Pallas packed kernels (interpret mode) and ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_gemm import binary_gemm
from compile.kernels.binary_gemv import binary_gemv


def make_case(n, m, r, seed):
    rng = np.random.default_rng(seed)
    u = rng.standard_normal((n, r))
    v = rng.standard_normal((m, r))
    up = ref.pack_signs(u)
    vtp = ref.pack_signs(v.T)
    s1 = rng.uniform(0.2, 2.0, n).astype(np.float32)
    s2 = rng.uniform(0.2, 2.0, m).astype(np.float32)
    return up, vtp, s1, s2


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 80),
    cols=st.integers(1, 130),
    seed=st.integers(0, 2**31),
)
def test_pack_unpack_roundtrip(rows, cols, seed):
    rng = np.random.default_rng(seed)
    w = np.sign(rng.standard_normal((rows, cols)))
    w[w == 0] = 1.0
    packed = ref.pack_signs(w)
    assert packed.shape == (rows, (cols + 31) // 32)
    back = np.asarray(ref.unpack_signs(packed, cols))
    np.testing.assert_array_equal(back, w.astype(np.float32))


def test_pack_bit_layout_is_lsb_first():
    # Element j lives in word j//32, bit j%32 — shared with rust pack.rs.
    w = -np.ones((1, 40), dtype=np.float32)
    w[0, 0] = 1.0   # word 0, bit 0
    w[0, 33] = 1.0  # word 1, bit 1
    packed = ref.pack_signs(w)
    assert packed[0, 0] == 1
    assert packed[0, 1] == 2


# ---------------------------------------------------------------------------
# GEMV kernel vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 200),
    m=st.integers(1, 200),
    r=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_gemv_matches_ref(n, m, r, seed):
    up, vtp, s1, s2 = make_case(n, m, r, seed)
    rng = np.random.default_rng(seed + 1)
    x = rng.standard_normal(m).astype(np.float32)
    want = np.asarray(ref.binary_gemv_ref(up, vtp, s1, s2, x, n=n, m=m, r=r))
    got = np.asarray(binary_gemv(up, vtp, s1, s2, x, n=n, m=m, r=r))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemv_matches_dense_reconstruction():
    n, m, r = 64, 96, 24
    up, vtp, s1, s2 = make_case(n, m, r, 7)
    rng = np.random.default_rng(8)
    x = rng.standard_normal(m).astype(np.float32)
    w_hat = np.asarray(ref.dense_reconstruct(up, vtp, s1, s2, n=n, m=m, r=r))
    got = np.asarray(binary_gemv(up, vtp, s1, s2, x, n=n, m=m, r=r))
    np.testing.assert_allclose(got, w_hat @ x, rtol=1e-4, atol=1e-4)


def test_gemv_exact_at_tile_boundaries():
    # Shapes exactly at / around the TILE boundary (128).
    for n in (127, 128, 129):
        up, vtp, s1, s2 = make_case(n, 64, 32, n)
        x = np.random.default_rng(n).standard_normal(64).astype(np.float32)
        want = np.asarray(ref.binary_gemv_ref(up, vtp, s1, s2, x, n=n, m=64, r=32))
        got = np.asarray(binary_gemv(up, vtp, s1, s2, x, n=n, m=64, r=32))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# GEMM kernel vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 20),
    n=st.integers(1, 150),
    m=st.integers(1, 150),
    r=st.integers(1, 64),
    seed=st.integers(0, 2**31),
)
def test_gemm_matches_ref(b, n, m, r, seed):
    up, vtp, s1, s2 = make_case(n, m, r, seed)
    rng = np.random.default_rng(seed + 2)
    x = rng.standard_normal((b, m)).astype(np.float32)
    want = np.asarray(ref.binary_gemm_ref(up, vtp, s1, s2, x, n=n, m=m, r=r))
    got = np.asarray(binary_gemm(up, vtp, s1, s2, x, n=n, m=m, r=r))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_consistent_with_gemv_rows():
    n, m, r = 40, 56, 16
    up, vtp, s1, s2 = make_case(n, m, r, 11)
    rng = np.random.default_rng(12)
    x = rng.standard_normal((3, m)).astype(np.float32)
    batch = np.asarray(binary_gemm(up, vtp, s1, s2, x, n=n, m=m, r=r))
    for i in range(3):
        row = np.asarray(binary_gemv(up, vtp, s1, s2, x[i], n=n, m=m, r=r))
        np.testing.assert_allclose(batch[i], row, rtol=1e-4, atol=1e-4)


def test_scales_apply_in_the_right_places():
    # Doubling s1 doubles y; doubling s2 doubles y (linear in both).
    n, m, r = 16, 24, 8
    up, vtp, s1, s2 = make_case(n, m, r, 13)
    x = np.random.default_rng(14).standard_normal(m).astype(np.float32)
    base = np.asarray(binary_gemv(up, vtp, s1, s2, x, n=n, m=m, r=r))
    y1 = np.asarray(binary_gemv(up, vtp, 2 * s1, s2, x, n=n, m=m, r=r))
    y2 = np.asarray(binary_gemv(up, vtp, s1, 2 * s2, x, n=n, m=m, r=r))
    np.testing.assert_allclose(y1, 2 * base, rtol=1e-5)
    np.testing.assert_allclose(y2, 2 * base, rtol=1e-5)
