"""L2 model graph tests: shapes, causality, engine consistency, decode/fwd
parity, and the flatten/unflatten calling convention used by the artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def cfg():
    return M.family_config("l2", "xs")


def test_forward_shapes(cfg):
    params = M.init_params(cfg, 0)
    tokens = jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) % 250
    logits = M.model_forward(cfg, params, tokens)
    assert logits.shape == (2, 8, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_causality(cfg):
    params = M.init_params(cfg, 1)
    t1 = jnp.array([[5, 6, 7, 8, 9, 10, 11, 12]], dtype=jnp.int32)
    t2 = t1.at[0, 7].set(99)
    l1 = M.model_forward(cfg, params, t1)
    l2 = M.model_forward(cfg, params, t2)
    np.testing.assert_allclose(l1[0, :7], l2[0, :7], rtol=1e-6)
    assert float(jnp.abs(l1[0, 7] - l2[0, 7]).sum()) > 0


def test_quant_engines_agree(cfg):
    """pallas and naive engines compute the same quantized forward."""
    params = M.init_params(cfg, 2, quant_bpw=2.0)
    tokens = jnp.arange(6, dtype=jnp.int32).reshape(1, 6)
    lp = M.model_forward(cfg, params, tokens, engine="pallas")
    ln = M.model_forward(cfg, params, tokens, engine="naive")
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ln), rtol=1e-3, atol=1e-3)


def test_decode_matches_forward_dense(cfg):
    params = M.init_params(cfg, 3)
    tokens = np.array([3, 14, 15, 92, 65, 35], dtype=np.int32)
    full = M.model_forward(cfg, params, jnp.asarray(tokens[None, :]))
    kv = cfg.n_kv_heads * cfg.head_dim
    k_cache = jnp.zeros((cfg.n_layers, cfg.max_seq, kv), jnp.float32)
    v_cache = jnp.zeros((cfg.n_layers, cfg.max_seq, kv), jnp.float32)
    for pos, tok in enumerate(tokens):
        logits, k_cache, v_cache = M.decode_step(
            cfg, params, jnp.int32(tok), jnp.int32(pos), k_cache, v_cache,
            engine="dense",
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[0, pos]), rtol=1e-3, atol=1e-4
        )


def test_decode_matches_forward_quant(cfg):
    params = M.init_params(cfg, 4, quant_bpw=2.0)
    tokens = np.array([3, 14, 15], dtype=np.int32)
    full = M.model_forward(cfg, params, jnp.asarray(tokens[None, :]), engine="naive")
    kv = cfg.n_kv_heads * cfg.head_dim
    k_cache = jnp.zeros((cfg.n_layers, cfg.max_seq, kv), jnp.float32)
    v_cache = jnp.zeros((cfg.n_layers, cfg.max_seq, kv), jnp.float32)
    for pos, tok in enumerate(tokens):
        logits, k_cache, v_cache = M.decode_step(
            cfg, params, jnp.int32(tok), jnp.int32(pos), k_cache, v_cache,
            engine="naive",
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[0, pos]), rtol=1e-3, atol=1e-3
        )


def test_flatten_unflatten_roundtrip(cfg):
    for bpw in (None, 1.0):
        params = M.init_params(cfg, 5, quant_bpw=bpw)
        flat = M.flatten_params(cfg, params)
        back = M.unflatten_params(cfg, flat, quant_bpw=bpw)
        tokens = jnp.arange(4, dtype=jnp.int32).reshape(1, 4)
        engine = "dense" if bpw is None else "naive"
        a = M.model_forward(cfg, params, tokens, engine=engine)
        b = M.model_forward(cfg, back, tokens, engine=engine)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_rank_for_bpw_matches_rust_convention():
    # round-half-away-from-zero, min 1 — must agree with rust scheme.rs.
    assert M.rank_for_bpw(4096, 4096, 1.0) == 2032
    assert M.rank_for_bpw(64, 64, 1.0) == 16
    assert M.rank_for_bpw(16, 16, 0.1) == 1  # clamped


def test_gqa_family(cfg):
    q3 = M.family_config("q3", "xs")
    assert q3.n_kv_heads < q3.n_heads
    params = M.init_params(q3, 6)
    tokens = jnp.arange(5, dtype=jnp.int32).reshape(1, 5)
    logits = M.model_forward(q3, params, tokens)
    assert bool(jnp.isfinite(logits).all())


def test_tied_embeddings_have_no_head():
    g3 = M.family_config("g3", "xs")
    params = M.init_params(g3, 7)
    assert "head" not in params
    flat = M.flatten_params(g3, params)
    back = M.unflatten_params(g3, flat)
    assert "head" not in back
