"""Pure-jnp reference implementations (the correctness oracle for the
Pallas kernels) and the shared bit-packing utilities.

Packing format (shared verbatim with rust/src/quant/pack.rs):
row-major; element j of a row lives in u32 word j // 32, bit j % 32
(LSB-first). +1 -> bit 1, -1 -> bit 0. Rows are padded to whole words with
zero bits; `cols` is carried separately so padding never contributes.
"""

import jax.numpy as jnp
import numpy as np


def pack_signs(w) -> np.ndarray:
    """Pack the signs of a [rows, cols] array into u32 words [rows, ceil(cols/32)].

    sign convention: w >= 0 -> bit 1 (+1), w < 0 -> bit 0 (-1).
    """
    w = np.asarray(w)
    rows, cols = w.shape
    wpr = (cols + 31) // 32
    bits = (w >= 0).astype(np.uint32)
    padded = np.zeros((rows, wpr * 32), dtype=np.uint32)
    padded[:, :cols] = bits
    shifts = np.arange(32, dtype=np.uint32)
    words = (padded.reshape(rows, wpr, 32) << shifts[None, None, :]).sum(
        axis=2, dtype=np.uint32
    )
    return words


def unpack_signs(words, cols: int) -> jnp.ndarray:
    """Unpack u32 words [rows, wpr] back to a ±1 float32 array [rows, cols]."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    rows, wpr = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    flat = bits.reshape(rows, wpr * 32)[:, :cols]
    return flat.astype(jnp.float32) * 2.0 - 1.0


def binary_gemv_ref(u_packed, vt_packed, s1, s2, x, *, n, m, r):
    """Reference two-stage packed binary low-rank GEMV.

    y = diag(s1) . U±1 . (V±1^T . (diag(s2) . x))
    u_packed: [n, ceil(r/32)], vt_packed: [r, ceil(m/32)].
    """
    u = unpack_signs(u_packed, r)  # [n, r]
    vt = unpack_signs(vt_packed, m)  # [r, m]
    xs = x * s2
    t = vt @ xs  # [r]
    return s1 * (u @ t)


def binary_gemm_ref(u_packed, vt_packed, s1, s2, x, *, n, m, r):
    """Batched reference: x [b, m] -> y [b, n]."""
    u = unpack_signs(u_packed, r)
    vt = unpack_signs(vt_packed, m)
    xs = x * s2[None, :]
    t = xs @ vt.T  # [b, r]
    return (t @ u.T) * s1[None, :]


def dense_reconstruct(u_packed, vt_packed, s1, s2, *, n, m, r):
    """Materialize Ŵ = diag(s1) U V^T diag(s2) (the naive-unpack engine)."""
    u = unpack_signs(u_packed, r)
    vt = unpack_signs(vt_packed, m)
    return s1[:, None] * (u @ vt) * s2[None, :]
