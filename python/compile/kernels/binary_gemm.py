"""Layer-1 Pallas kernel: packed binary low-rank GEMM (batched inference).

The Marlin-style batched kernel of paper Appendix E.3, rethought for the
MXU: the ±1 tile expanded in VMEM feeds a dense [TILE_B, cols] x
[cols, TILE_N] matmul — exactly the shape the 128x128 systolic array wants
(the CUDA version uses mma.sync 16x8x16 tiles + cp.async pipelining; on
TPU the BlockSpec grid expresses the same HBM→VMEM pipeline and the MXU
replaces the tensor cores).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128  # output-feature tile
TILE_B = 8    # batch tile


def _unpack_tile(words, cols):
    rows, wpr = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    flat = bits.reshape(rows, wpr * 32)[:, :cols]
    return flat.astype(jnp.float32) * 2.0 - 1.0


def _gemm_stage_kernel(w_ref, x_ref, scale_ref, o_ref, *, cols):
    """o[b_tile, n_tile] = x[b_tile, :] @ W±1[n_tile, :]ᵀ ⊙ scale[n_tile]."""
    w_tile = _unpack_tile(w_ref[...], cols)  # [TILE_N, cols]
    x = x_ref[...]  # [TILE_B, cols]
    # MXU-shaped contraction: [TILE_B, cols] @ [cols, TILE_N].
    o_ref[...] = (x @ w_tile.T) * scale_ref[...][None, :]


def _padded(n, t):
    return ((n + t - 1) // t) * t


def packed_matmul(w_packed, x, scale, *, rows: int, cols: int):
    """x [b, cols] @ W±1ᵀ [cols, rows] ⊙ scale — batched packed stage."""
    b = x.shape[0]
    wpr = w_packed.shape[1]
    rows_p = _padded(rows, TILE_N)
    b_p = _padded(b, TILE_B)
    if rows_p != rows:
        w_packed = jnp.pad(w_packed, ((0, rows_p - rows), (0, 0)))
        scale = jnp.pad(scale, (0, rows_p - rows))
    if b_p != b:
        x = jnp.pad(x, ((0, b_p - b), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_gemm_stage_kernel, cols=cols),
        grid=(b_p // TILE_B, rows_p // TILE_N),
        in_specs=[
            pl.BlockSpec((TILE_N, wpr), lambda bi, ni: (ni, 0)),
            pl.BlockSpec((TILE_B, cols), lambda bi, ni: (bi, 0)),
            pl.BlockSpec((TILE_N,), lambda bi, ni: (ni,)),
        ],
        out_specs=pl.BlockSpec((TILE_B, TILE_N), lambda bi, ni: (bi, ni)),
        out_shape=jax.ShapeDtypeStruct((b_p, rows_p), jnp.float32),
        interpret=True,
    )(w_packed, x, scale)
    return out[:b, :rows]


def binary_gemm(u_packed, vt_packed, s1, s2, x, *, n: int, m: int, r: int):
    """Batched packed binary low-rank GEMM: x [b, m] -> y [b, n]."""
    ones_r = jnp.ones((r,), jnp.float32)
    t = packed_matmul(vt_packed, x * s2[None, :], ones_r, rows=r, cols=m)
    return packed_matmul(u_packed, t, s1, rows=n, cols=r)
