"""Layer-1 Pallas kernels: packed binary low-rank GEMV.

The paper's CUDA GEMV kernel (Appendix E.2) rethought for TPU/Pallas:

  stage 1:  t = V±1ᵀ (s2 ⊙ x)      — reduce over the input dim
  stage 2:  y = s1 ⊙ (U±1 t)       — reduce over the rank dim

Hardware-adaptation choices (DESIGN.md §8):
- Weights cross HBM as packed u32 words; the ±1 expansion
  (shift → mask → 2b−1, VPU-friendly broadcast ops, not warp ballots)
  exists only inside the kernel, i.e. only in VMEM.
- BlockSpec tiles the *output* dimension so each grid step streams one
  `[TILE, words_per_row]` packed panel into VMEM — this is the HBM→VMEM
  schedule that the CUDA version expresses with threadblocks.
- The rank-r intermediate `t` stays resident between the two stages
  (as the CUDA kernel keeps it in shared memory).
- Channel scales fuse into the stages' epilogues (s2 pre-scale, s1
  post-scale), mirroring the fused FMA of the CUDA kernel.

interpret=True is mandatory on this CPU-PJRT stack: real TPU lowering
emits Mosaic custom-calls the CPU plugin cannot execute. The BlockSpec
structure is still what a real TPU run would use; VMEM footprints are
estimated in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile per grid step (rows of the packed matrix handled at once).
# 128 rows aligns with the TPU lane width; see DESIGN.md §Perf for the
# VMEM budget at this setting.
TILE = 128


def _unpack_tile(words, cols):
    """[rows, wpr] u32 -> [rows, cols] ±1 f32 (in-kernel expansion)."""
    rows, wpr = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    flat = bits.reshape(rows, wpr * 32)[:, :cols]
    return flat.astype(jnp.float32) * 2.0 - 1.0


def _stage_kernel(w_ref, x_ref, scale_ref, o_ref, *, cols):
    """One fused stage: o = scale ⊙ (W±1 @ x) for a packed row-tile of W."""
    w_tile = _unpack_tile(w_ref[...], cols)  # [TILE, cols] ±1, VMEM only
    x = x_ref[...]  # [cols]
    o_ref[...] = scale_ref[...] * (w_tile @ x)


def _padded(n: int, tile: int) -> int:
    return ((n + tile - 1) // tile) * tile


def packed_matvec(w_packed, x, scale, *, rows: int, cols: int, tile: int = TILE):
    """scale ⊙ (W±1 @ x) with W packed [rows, ceil(cols/32)] u32.

    Grid over row tiles; each step sees one packed panel (BlockSpec) and
    the full x vector (VMEM-resident: cols ≤ a few thousand f32).
    """
    wpr = w_packed.shape[1]
    rows_p = _padded(rows, tile)
    if rows_p != rows:
        w_packed = jnp.pad(w_packed, ((0, rows_p - rows), (0, 0)))
        scale = jnp.pad(scale, (0, rows_p - rows))
    out = pl.pallas_call(
        functools.partial(_stage_kernel, cols=cols),
        grid=(rows_p // tile,),
        in_specs=[
            pl.BlockSpec((tile, wpr), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows_p,), jnp.float32),
        interpret=True,
    )(w_packed, x, scale)
    return out[:rows]


def binary_gemv(u_packed, vt_packed, s1, s2, x, *, n: int, m: int, r: int):
    """Two-stage packed binary low-rank GEMV (the L1 kernel).

    u_packed: [n, ceil(r/32)] u32, vt_packed: [r, ceil(m/32)] u32,
    s1: [n], s2: [m], x: [m] -> y: [n].
    """
    ones_r = jnp.ones((r,), jnp.float32)
    # Stage 1: t = V±1ᵀ (s2 ⊙ x); the s2 scale fuses into the stage input.
    t = packed_matvec(vt_packed, x * s2, ones_r, rows=r, cols=m)
    # Stage 2: y = s1 ⊙ (U±1 t).
    return packed_matvec(u_packed, t, s1, rows=n, cols=r)
