"""Layer-2 JAX model: the same Llama-style decoder as rust/src/nn
(RMSNorm, RoPE, causal MHA/GQA, SwiGLU, tied/untied head), in both dense
and quantized (L1-kernel-backed) forms, plus single-token decode graphs
with KV caches. AOT-lowered to HLO text by aot.py; numerical parity with
the Rust implementation is enforced by rust/tests/runtime_parity.rs.

Parameter convention (must match the Rust side exactly):
- every linear is stored [d_out, d_in] and applied as y = x @ W.T
- canonical flat parameter order:
    embed, (ln1, wq, wk, wv, wo, ln2, wg, wu, wd) per block, ln_f[, head]
- quantized linears are replaced by (u_packed, vt_packed, s1, s2).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.binary_gemm import binary_gemm
from .kernels.binary_gemv import binary_gemv
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    max_seq: int
    rope_theta: float
    tied: bool
    eps: float = 1e-5

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


def family_config(family: str, size: str) -> Config:
    """Mirror of rust nn::family_config."""
    dims = {"xs": (64, 2, 4), "s": (128, 4, 4), "m": (192, 6, 6), "l": (256, 8, 8)}
    d_model, n_layers, n_heads = dims[size]
    d_ff = d_model * 8 // 3 // 8 * 8
    n_kv = n_heads
    theta = 10_000.0
    tied = False
    if family == "l3":
        n_kv = max(n_heads // 2, 1)
    elif family == "g3":
        tied = True
        d_ff = d_model * 4
    elif family == "q3":
        n_kv = max(n_heads // 2, 1)
        theta = 100_000.0
    elif family == "r1":
        d_ff = d_model * 2
    elif family != "l2":
        raise ValueError(f"unknown family {family}")
    return Config(
        name=f"{family}-{size}",
        vocab=257,
        d_model=d_model,
        n_layers=n_layers,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=d_ff,
        max_seq=128,
        rope_theta=theta,
        tied=tied,
    )


def rank_for_bpw(n: int, m: int, bpw: float) -> int:
    """Mirror of rust quant::scheme::rank_for_bpw (round half away from 0)."""
    r = bpw * n * m / (n + m) - 16.0
    return max(int(np.floor(r + 0.5)), 1)


# ---------------------------------------------------------------------------
# Core ops (must match the Rust math).
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps):
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x, positions, n_heads, hd, theta):
    """x: [..., n_heads*hd]; rotate pairs (i, i+half) per head."""
    half = hd // 2
    shape = x.shape[:-1] + (n_heads, hd)
    xh = x.reshape(shape)
    a = xh[..., :half]
    b = xh[..., half:]
    inv_freq = 1.0 / (theta ** (2.0 * jnp.arange(half) / hd))
    angle = positions[..., None, None] * inv_freq[None, None, :]
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    ra = a * cos - b * sin
    rb = a * sin + b * cos
    return jnp.concatenate([ra, rb], axis=-1).reshape(x.shape)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# Linear-layer abstraction: dense weights or packed quantized tuples.
# ---------------------------------------------------------------------------


def linear_apply(w, x, *, engine: str):
    """Apply a linear layer. `w` is either a dense [n, m] array or a tuple
    (u_packed, vt_packed, s1, s2, (n, m, r)) of packed binary factors.
    `x` is [..., m]. engine: dense|pallas|naive.
    """
    if not isinstance(w, tuple):
        return x @ w.T
    u_packed, vt_packed, s1, s2, (n, m, r) = w
    if engine == "naive":
        w_hat = ref.dense_reconstruct(u_packed, vt_packed, s1, s2, n=n, m=m, r=r)
        return x @ w_hat.T
    if engine == "pallas":
        lead = x.shape[:-1]
        xb = x.reshape((-1, m))
        if xb.shape[0] == 1:
            y = binary_gemv(u_packed, vt_packed, s1, s2, xb[0], n=n, m=m, r=r)[None, :]
        else:
            y = binary_gemm(u_packed, vt_packed, s1, s2, xb, n=n, m=m, r=r)
        return y.reshape(lead + (n,))
    raise ValueError(f"unknown engine {engine}")


# ---------------------------------------------------------------------------
# Full-sequence forward.
# ---------------------------------------------------------------------------


def block_forward(cfg: Config, bw, x, *, engine: str):
    """bw: dict with ln1, wq, wk, wv, wo, ln2, wg, wu, wd. x: [B, S, D]."""
    bsz, seq, d = x.shape
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    h1 = rmsnorm(x, bw["ln1"], cfg.eps)
    q = linear_apply(bw["wq"], h1, engine=engine)
    k = linear_apply(bw["wk"], h1, engine=engine)
    v = linear_apply(bw["wv"], h1, engine=engine)
    positions = jnp.arange(seq, dtype=jnp.float32)[None, :].repeat(bsz, 0)
    q = rope(q, positions, cfg.n_heads, hd, cfg.rope_theta)
    k = rope(k, positions, cfg.n_kv_heads, hd, cfg.rope_theta)

    qh = q.reshape(bsz, seq, cfg.n_heads, hd)
    kh = k.reshape(bsz, seq, cfg.n_kv_heads, hd)
    vh = v.reshape(bsz, seq, cfg.n_kv_heads, hd)
    # Expand KV heads for GQA.
    kh = jnp.repeat(kh, groups, axis=2)
    vh = jnp.repeat(vh, groups, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", qh, kh) / np.sqrt(hd)
    causal = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    scores = jnp.where(causal[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    att = jnp.einsum("bhst,bthd->bshd", probs, vh).reshape(bsz, seq, cfg.n_heads * hd)
    x = x + linear_apply(bw["wo"], att, engine=engine)

    h2 = rmsnorm(x, bw["ln2"], cfg.eps)
    gate = linear_apply(bw["wg"], h2, engine=engine)
    up = linear_apply(bw["wu"], h2, engine=engine)
    x = x + linear_apply(bw["wd"], silu(gate) * up, engine=engine)
    return x


def model_forward(cfg: Config, params, tokens, *, engine: str = "dense"):
    """tokens: [B, S] int32 -> logits [B, S, vocab].

    params: dict {embed, blocks: [block dicts], ln_f, head?}.
    """
    x = params["embed"][tokens]
    for bw in params["blocks"]:
        x = block_forward(cfg, bw, x, engine=engine)
    x = rmsnorm(x, params["ln_f"], cfg.eps)
    head = params.get("head")
    if head is None:
        head = params["embed"]
    return x @ head.T


# ---------------------------------------------------------------------------
# Single-token decode with KV cache.
# ---------------------------------------------------------------------------


def decode_step(cfg: Config, params, token, pos, k_cache, v_cache, *, engine: str):
    """One decode step.

    token: [] int32, pos: [] int32,
    k_cache/v_cache: [n_layers, max_seq, n_kv_heads*hd].
    Returns (logits [vocab], new_k_cache, new_v_cache).
    """
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    x = params["embed"][token]  # [D]
    posf = pos.astype(jnp.float32)
    for li, bw in enumerate(params["blocks"]):
        h1 = rmsnorm(x, bw["ln1"], cfg.eps)
        q = linear_apply(bw["wq"], h1[None, :], engine=engine)[0]
        k = linear_apply(bw["wk"], h1[None, :], engine=engine)[0]
        v = linear_apply(bw["wv"], h1[None, :], engine=engine)[0]
        q = rope(q[None, :], posf[None], cfg.n_heads, hd, cfg.rope_theta)[0]
        k = rope(k[None, :], posf[None], cfg.n_kv_heads, hd, cfg.rope_theta)[0]
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None, None, :], (li, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None, None, :], (li, pos, 0))

        qh = q.reshape(cfg.n_heads, hd)
        kh = k_cache[li].reshape(cfg.max_seq, cfg.n_kv_heads, hd)
        vh = v_cache[li].reshape(cfg.max_seq, cfg.n_kv_heads, hd)
        kh = jnp.repeat(kh, groups, axis=1)  # [S, H, hd]
        vh = jnp.repeat(vh, groups, axis=1)
        scores = jnp.einsum("hd,shd->hs", qh, kh) / np.sqrt(hd)
        valid = jnp.arange(cfg.max_seq) <= pos
        scores = jnp.where(valid[None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("hs,shd->hd", probs, vh).reshape(cfg.n_heads * hd)
        x = x + linear_apply(bw["wo"], att[None, :], engine=engine)[0]

        h2 = rmsnorm(x, bw["ln2"], cfg.eps)
        gate = linear_apply(bw["wg"], h2[None, :], engine=engine)[0]
        up = linear_apply(bw["wu"], h2[None, :], engine=engine)[0]
        x = x + linear_apply(bw["wd"], (silu(gate) * up)[None, :], engine=engine)[0]
    x = rmsnorm(x, params["ln_f"], cfg.eps)
    head = params.get("head")
    if head is None:
        head = params["embed"]
    logits = x @ head.T
    return logits, k_cache, v_cache


# ---------------------------------------------------------------------------
# Parameter initialization / flattening (the artifact calling convention).
# ---------------------------------------------------------------------------

LINEAR_NAMES = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]


def linear_shapes(cfg: Config):
    d, hd = cfg.d_model, cfg.head_dim
    kv = cfg.n_kv_heads * hd
    return {
        "wq": (d, d),
        "wk": (kv, d),
        "wv": (kv, d),
        "wo": (d, d),
        "wg": (cfg.d_ff, d),
        "wu": (cfg.d_ff, d),
        "wd": (d, cfg.d_ff),
    }


def init_params(cfg: Config, seed: int = 0, *, quant_bpw: float | None = None):
    """Random params (dense, or packed-quantized when quant_bpw given)."""
    rng = np.random.default_rng(seed)
    shapes = linear_shapes(cfg)

    def dense(shape, std=0.02):
        return jnp.asarray(rng.standard_normal(shape) * std, jnp.float32)

    def make_linear(name):
        w = rng.standard_normal(shapes[name]) * 0.02
        if quant_bpw is None:
            return jnp.asarray(w, jnp.float32)
        n, m = shapes[name]
        r = rank_for_bpw(n, m, quant_bpw)
        u = rng.standard_normal((n, r))
        v = rng.standard_normal((m, r))
        s1 = rng.uniform(0.01, 0.05, n).astype(np.float32)
        s2 = rng.uniform(0.5, 1.5, m).astype(np.float32)
        return (
            jnp.asarray(ref.pack_signs(u)),
            jnp.asarray(ref.pack_signs(v.T)),
            jnp.asarray(s1),
            jnp.asarray(s2),
            (n, m, r),
        )

    blocks = []
    for _ in range(cfg.n_layers):
        blocks.append(
            {
                "ln1": jnp.ones(cfg.d_model, jnp.float32),
                "ln2": jnp.ones(cfg.d_model, jnp.float32),
                **{name: make_linear(name) for name in LINEAR_NAMES},
            }
        )
    params = {
        "embed": dense((cfg.vocab, cfg.d_model)),
        "blocks": blocks,
        "ln_f": jnp.ones(cfg.d_model, jnp.float32),
    }
    if not cfg.tied:
        params["head"] = dense((cfg.vocab, cfg.d_model))
    return params


def flatten_params(cfg: Config, params):
    """Canonical flat list (the artifact argument order)."""
    flat = [params["embed"]]
    for bw in params["blocks"]:
        flat.append(bw["ln1"])
        for name in LINEAR_NAMES:
            w = bw[name]
            if isinstance(w, tuple):
                flat.extend(w[:4])  # u_packed, vt_packed, s1, s2
            else:
                flat.append(w)
        flat.append(bw["ln2"])
    flat.append(params["ln_f"])
    if "head" in params:
        flat.append(params["head"])
    return flat


def unflatten_params(cfg: Config, flat, *, quant_bpw: float | None = None):
    """Inverse of flatten_params (given the same quantization layout)."""
    shapes = linear_shapes(cfg)
    it = iter(flat)
    params = {"embed": next(it), "blocks": []}
    for _ in range(cfg.n_layers):
        bw = {"ln1": next(it)}
        for name in LINEAR_NAMES:
            if quant_bpw is None:
                bw[name] = next(it)
            else:
                n, m = shapes[name]
                r = rank_for_bpw(n, m, quant_bpw)
                bw[name] = (next(it), next(it), next(it), next(it), (n, m, r))
        bw["ln2"] = next(it)
        params["blocks"].append(bw)
    params["ln_f"] = next(it)
    if not cfg.tied:
        params["head"] = next(it)
    return params


def forward_fn(cfg: Config, *, engine: str, quant_bpw: float | None, batch: int, seq: int):
    """A jit-able f(*flat_params, tokens) -> logits for AOT lowering."""

    def fn(*args):
        flat, tokens = list(args[:-1]), args[-1]
        params = unflatten_params(cfg, flat, quant_bpw=quant_bpw)
        return (model_forward(cfg, params, tokens, engine=engine),)

    return fn


def decode_fn(cfg: Config, *, engine: str, quant_bpw: float | None):
    """A jit-able f(*flat_params, token, pos, k_cache, v_cache)."""

    def fn(*args):
        flat = list(args[:-4])
        token, pos, k_cache, v_cache = args[-4:]
        params = unflatten_params(cfg, flat, quant_bpw=quant_bpw)
        logits, nk, nv = decode_step(
            cfg, params, token, pos, k_cache, v_cache, engine=engine
        )
        return (logits, nk, nv)

    return fn


def example_args(cfg: Config, *, quant_bpw: float | None, batch: int, seq: int, mode: str):
    """ShapeDtypeStructs for lowering."""
    params = init_params(cfg, 0, quant_bpw=quant_bpw)
    flat = flatten_params(cfg, params)
    specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat]
    if mode == "forward":
        specs.append(jax.ShapeDtypeStruct((batch, seq), jnp.int32))
    elif mode == "decode":
        kv = cfg.n_kv_heads * cfg.head_dim
        specs.append(jax.ShapeDtypeStruct((), jnp.int32))  # token
        specs.append(jax.ShapeDtypeStruct((), jnp.int32))  # pos
        specs.append(jax.ShapeDtypeStruct((cfg.n_layers, cfg.max_seq, kv), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((cfg.n_layers, cfg.max_seq, kv), jnp.float32))
    else:
        raise ValueError(mode)
    return specs
