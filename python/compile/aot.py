"""AOT export: lower the L2 JAX graphs (with L1 Pallas kernels inside) to
HLO *text* artifacts consumed by the Rust runtime.

HLO text — NOT `lowered.compiler_ir("hlo")`-proto serialization — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published `xla` crate wraps)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run from python/: `python -m compile.aot --out ../artifacts`
(`make artifacts` wraps this and is a no-op when sources are unchanged).

Exported set (see DESIGN.md §7):
  <cfg>_fwd_dense       logits = f(params..., tokens[B,S])
  <cfg>_fwd_quant       same, every decoder linear through the Pallas
                        packed binary kernels (rank from --bpw)
  <cfg>_decode_dense    single-token decode with KV cache
  <cfg>_decode_quant    same through the Pallas kernels
  <cfg>_decode_naive    quantized but dense-dequantize (GemLite-like)
  gemv_<n>x<m>x<r>_{pallas,naive,dense}  kernel micro-graphs (Figs. 10-13)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels.binary_gemv import binary_gemv


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(out_dir: str, name: str, text: str, manifest: dict, meta: dict):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest[name] = {"file": f"{name}.hlo.txt", **meta}
    print(f"  wrote {name} ({len(text) / 1e6:.2f} MB)")


def export_model_graphs(cfg: M.Config, out_dir: str, manifest: dict, *, bpw: float,
                        batch: int, seq: int):
    base = cfg.name.replace("-", "_")
    shapes = M.linear_shapes(cfg)
    ranks = {k: M.rank_for_bpw(n, m, bpw) for k, (n, m) in shapes.items()}

    # Dense full-sequence forward.
    fn = M.forward_fn(cfg, engine="dense", quant_bpw=None, batch=batch, seq=seq)
    args = M.example_args(cfg, quant_bpw=None, batch=batch, seq=seq, mode="forward")
    write(out_dir, f"{base}_fwd_dense", to_hlo_text(jax.jit(fn).lower(*args)), manifest,
          {"kind": "forward", "engine": "dense", "config": cfg.name, "batch": batch,
           "seq": seq, "quant_bpw": None})

    # Quantized full-sequence forward (Pallas kernels).
    fn = M.forward_fn(cfg, engine="pallas", quant_bpw=bpw, batch=batch, seq=seq)
    args = M.example_args(cfg, quant_bpw=bpw, batch=batch, seq=seq, mode="forward")
    write(out_dir, f"{base}_fwd_quant", to_hlo_text(jax.jit(fn).lower(*args)), manifest,
          {"kind": "forward", "engine": "pallas", "config": cfg.name, "batch": batch,
           "seq": seq, "quant_bpw": bpw, "ranks": ranks})

    # Decode graphs.
    for engine, qb, name in [
        ("dense", None, f"{base}_decode_dense"),
        ("pallas", bpw, f"{base}_decode_quant"),
        ("naive", bpw, f"{base}_decode_naive"),
    ]:
        fn = M.decode_fn(cfg, engine=engine, quant_bpw=qb)
        args = M.example_args(cfg, quant_bpw=qb, batch=1, seq=seq, mode="decode")
        write(out_dir, name, to_hlo_text(jax.jit(fn).lower(*args)), manifest,
              {"kind": "decode", "engine": engine, "config": cfg.name,
               "max_seq": cfg.max_seq, "quant_bpw": qb,
               "ranks": ranks if qb else None})


def export_kernel_micrographs(out_dir: str, manifest: dict):
    """Isolated kernel graphs for the Fig. 10-13 benches."""
    shapes = [(256, 256, 112), (512, 512, 240), (1024, 1024, 496)]
    for (n, m, r) in shapes:
        wpr_r = (r + 31) // 32
        wpr_m = (m + 31) // 32
        specs_common = [
            jax.ShapeDtypeStruct((n, wpr_r), jnp.uint32),
            jax.ShapeDtypeStruct((r, wpr_m), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ]

        def pallas_fn(up, vtp, s1, s2, x):
            return (binary_gemv(up, vtp, s1, s2, x, n=n, m=m, r=r),)

        def naive_fn(up, vtp, s1, s2, x):
            w = ref.dense_reconstruct(up, vtp, s1, s2, n=n, m=m, r=r)
            return (w @ x,)

        def dense_fn(w, x):
            return (w @ x,)

        write(out_dir, f"gemv_{n}x{m}x{r}_pallas",
              to_hlo_text(jax.jit(pallas_fn).lower(*specs_common)), manifest,
              {"kind": "gemv", "engine": "pallas", "n": n, "m": m, "r": r})
        write(out_dir, f"gemv_{n}x{m}x{r}_naive",
              to_hlo_text(jax.jit(naive_fn).lower(*specs_common)), manifest,
              {"kind": "gemv", "engine": "naive", "n": n, "m": m, "r": r})
        dense_specs = [
            jax.ShapeDtypeStruct((n, m), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ]
        write(out_dir, f"gemv_{n}x{m}_dense",
              to_hlo_text(jax.jit(dense_fn).lower(*dense_specs)), manifest,
              {"kind": "gemv", "engine": "dense", "n": n, "m": m, "r": None})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default="l2-s", help="family-size, e.g. l2-s")
    ap.add_argument("--bpw", type=float, default=1.0)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {}
    family, size = args.config.split("-")
    cfg = M.family_config(family, size)
    print(f"[aot] exporting graphs for {cfg.name} (bpw={args.bpw})")
    export_model_graphs(cfg, args.out, manifest, bpw=args.bpw, batch=args.batch,
                        seq=args.seq)
    if not args.skip_kernels:
        print("[aot] exporting kernel micro-graphs")
        export_kernel_micrographs(args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] {len(manifest)} artifacts -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
